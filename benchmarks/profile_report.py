"""Text summary of a serve_bench hot-loop profile: phase table, MFU,
and costmodel-drift reconciliation.

    PYTHONPATH=src python benchmarks/profile_report.py profile.json

Loads + structurally validates the profile JSON written by
``serve_bench --profile-out`` (schema ``repro.profile.v1``), then
prints:

- the per-phase table: measured seconds (forward time attributed to the
  phase), ledger-predicted seconds, measured share of the forward, and
  the cumulative measured/predicted drift ratio,
- the headline utilization numbers: MFU (useful model flops over
  measured seconds at the BF16 peak), roofline fraction, and the
  costmodel ``time_scale`` EWMA the replan cost gates calibrate with,
- the kernel-PR acceptance number from ROADMAP item 1: the
  ``dispatch + quantize_fp4`` share of the forward.

Two accounting-integrity invariants are enforced (the same discipline as
``trace_report.py``'s migration reconciliation):

1. **Exhaustive attribution** — the per-phase measured seconds must sum
   to the run's total forward seconds (the profiler attributes every
   measured second to exactly one phase).
2. **MFU consistency** — ``mfu * (PEAK_BF16 * forward_s)`` must equal
   the cumulative useful model flops.

Exit status is non-zero when the profile fails validation (1) or either
reconciliation diverges beyond tolerance (2), so CI can use the report
as a cheap profile-integrity check.  Per-phase *drift* (measured vs
predicted) is reported but not gated — mixed prefill/decode iterations
legitimately shift the share vector; ``--drift-tolerance`` turns it
into a gate for controlled single-regime runs.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict

from repro.configs.hw import PEAK_BF16
from repro.obs.profiler import PROFILE_SCHEMA
from repro.obs.ledger import PHASES

RECONCILE_RTOL = 1e-6
RECONCILE_ATOL = 1e-12


def load_profile(path: str) -> Dict:
    with open(path) as f:
        obj = json.load(f)
    if not isinstance(obj, dict):
        raise ValueError("profile must be a JSON object")
    if obj.get("schema") != PROFILE_SCHEMA:
        raise ValueError(f"schema {obj.get('schema')!r} != "
                         f"{PROFILE_SCHEMA!r}")
    phases = obj.get("phases")
    if not isinstance(phases, dict) or not phases:
        raise ValueError("missing/empty 'phases' object")
    for ph, rec in phases.items():
        if not isinstance(rec, dict) or "measured_s" not in rec \
                or "predicted_s" not in rec:
            raise ValueError(f"phase {ph!r} needs measured_s/predicted_s")
    totals = obj.get("totals")
    if not isinstance(totals, dict):
        raise ValueError("missing 'totals' object")
    for key in ("forward_s", "model_flops", "mfu"):
        if key not in totals:
            raise ValueError(f"totals missing {key!r}")
    return obj


def _close(got: float, want: float, rtol: float) -> bool:
    return abs(got - want) <= RECONCILE_ATOL + rtol * abs(want)


def report(path: str, rtol: float = RECONCILE_RTOL,
           drift_tolerance: float = 0.0) -> int:
    try:
        obj = load_profile(path)
    except (ValueError, OSError, json.JSONDecodeError) as e:
        print(f"INVALID profile {path}: {e}", file=sys.stderr)
        return 1
    meta = obj.get("metadata", {})
    totals = obj["totals"]
    phases = obj["phases"]
    fwd_s = float(totals["forward_s"])
    print(f"profile {path}: {obj.get('n_iters')} iters"
          + (f", arm={meta.get('arm')}" if meta.get("arm") else "")
          + (f", arch={meta.get('arch')}" if meta.get("arch") else "")
          + (", virtual time" if meta.get("virtual_time") else ""))

    order = [ph for ph in PHASES if ph in phases] \
        + [ph for ph in phases if ph not in PHASES]
    print(f"\n{'phase':14s} {'measured ms':>12s} {'predicted ms':>13s} "
          f"{'share':>7s} {'drift':>7s}")
    for ph in order:
        meas = float(phases[ph]["measured_s"])
        pred = float(phases[ph]["predicted_s"])
        share = meas / fwd_s if fwd_s > 0 else 0.0
        drift = meas / pred if pred > 0 else float("nan")
        print(f"{ph:14s} {meas * 1e3:12.4f} {pred * 1e3:13.4f} "
              f"{share:7.3f} {drift:7.3f}")

    mfu = float(totals["mfu"])
    print(f"\nMFU {mfu:.4f}"
          + (f"  roofline_fraction {totals['roofline_fraction']:.4f}"
             if "roofline_fraction" in totals else "")
          + (f"  costmodel time_scale {totals['time_scale']:.4f}"
             if "time_scale" in totals else ""))
    # the ROADMAP item-1 acceptance number: the share a fused Pallas
    # dispatch+quantize kernel must shrink
    kern = sum(float(phases[ph]["measured_s"])
               for ph in ("dispatch", "quantize_fp4") if ph in phases)
    if fwd_s > 0:
        print(f"dispatch+quantize_fp4 share: {kern / fwd_s:.3f} "
              "(ROADMAP item 1 kernel-PR acceptance number)")

    rc = 0
    # 1) exhaustive attribution: phases partition the forward seconds
    meas_sum = sum(float(rec["measured_s"]) for rec in phases.values())
    ok = _close(meas_sum, fwd_s, rtol)
    print(f"reconcile attribution: sum(phase measured)={meas_sum:.9f}s "
          f"vs forward_s={fwd_s:.9f}s -> {'OK' if ok else 'MISMATCH'}")
    rc = rc or (0 if ok else 2)
    # 2) MFU consistency: the gauge must be the ledger flops over
    # measured seconds at the single-sourced BF16 peak
    want_flops = float(totals["model_flops"])
    got_flops = mfu * PEAK_BF16 * fwd_s
    ok = _close(got_flops, want_flops, rtol)
    print(f"reconcile mfu: mfu*peak*forward_s={got_flops:.6e} flops "
          f"vs model_flops={want_flops:.6e} -> "
          f"{'OK' if ok else 'MISMATCH'}")
    rc = rc or (0 if ok else 2)
    # 3) optional drift gate for controlled single-regime runs
    if drift_tolerance > 0:
        for ph in order:
            pred = float(phases[ph]["predicted_s"])
            if pred <= 0:
                continue
            drift = float(phases[ph]["measured_s"]) / pred
            scale = float(totals.get("time_scale", 1.0))
            if abs(drift / max(scale, 1e-12) - 1.0) > drift_tolerance:
                print(f"DRIFT phase {ph}: {drift:.3f} vs time_scale "
                      f"{scale:.3f} beyond {drift_tolerance:.2f}")
                rc = rc or 2
    return rc


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("profile", help="profile JSON from "
                                    "serve_bench --profile-out")
    ap.add_argument("--rtol", type=float, default=RECONCILE_RTOL,
                    help="relative tolerance for the attribution and "
                         "MFU reconciliation checks")
    ap.add_argument("--drift-tolerance", type=float, default=0.0,
                    help="gate per-phase drift vs the run's time_scale "
                         "beyond this relative tolerance (0 = report "
                         "only; leave 0 for mixed prefill/decode runs)")
    args = ap.parse_args(argv)
    return report(args.profile, rtol=args.rtol,
                  drift_tolerance=args.drift_tolerance)


if __name__ == "__main__":
    raise SystemExit(main())
