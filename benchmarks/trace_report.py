"""Text summary of a serve_bench Chrome-trace: top spans + migration
stall-vs-hidden attribution.

    PYTHONPATH=src python benchmarks/trace_report.py trace.json

Loads + structurally validates the trace JSON written by
``serve_bench --trace-out``, then prints:

- the top span names by total duration (count / total / mean / max ms),
- the migration attribution: summed ``migration.drain`` span durations
  split into stall vs hidden seconds (from each drain event's args) and
  reconciled against the run totals ``migration_s_total`` /
  ``migration_hidden_s_total`` carried in the trace metadata — the
  acceptance invariant is that they agree to float tolerance,
- the instant-event counts (dispatch decisions, table commits, elastic
  events) so a long run is skimmable without opening Perfetto.

Exit status is non-zero when the trace fails validation or the
migration reconciliation diverges beyond tolerance, so CI can use the
report as a cheap trace-integrity check.
"""
from __future__ import annotations

import argparse
import sys
from collections import defaultdict
from typing import Dict, List

from repro.obs.trace import load_trace, validate_chrome_trace

RECONCILE_RTOL = 1e-6
RECONCILE_ATOL = 1e-9


def span_table(events: List[Dict], top: int = 12) -> List[Dict]:
    """Aggregate "X" events by name: count / total / mean / max ms,
    sorted by total duration descending."""
    agg: Dict[str, List[float]] = defaultdict(list)
    for ev in events:
        if ev.get("ph") == "X":
            agg[ev["name"]].append(float(ev.get("dur", 0.0)) / 1e3)  # ms
    rows = [dict(name=name, count=len(ds), total_ms=sum(ds),
                 mean_ms=sum(ds) / len(ds), max_ms=max(ds))
            for name, ds in agg.items()]
    rows.sort(key=lambda r: -r["total_ms"])
    return rows[:top]


def instant_counts(events: List[Dict]) -> Dict[str, int]:
    out: Dict[str, int] = defaultdict(int)
    for ev in events:
        if ev.get("ph") == "i":
            out[ev["name"]] += 1
    return dict(sorted(out.items()))


def migration_attribution(events: List[Dict]) -> Dict[str, float]:
    """Sum the migration.drain spans and their stall/hidden args (all
    in seconds; event ts/dur are microseconds)."""
    total = stall = hidden = 0.0
    n = 0
    for ev in events:
        if ev.get("ph") == "X" and ev["name"] == "migration.drain":
            n += 1
            total += float(ev.get("dur", 0.0)) / 1e6
            args = ev.get("args") or {}
            stall += float(args.get("stall_s", 0.0))
            hidden += float(args.get("hidden_s", 0.0))
    return dict(n_drains=n, span_total_s=total, stall_s=stall,
                hidden_s=hidden)


def reconcile(attr: Dict[str, float], meta: Dict) -> bool:
    """The acceptance invariant: summed drain span durations must equal
    the engine's migration_s_total + migration_hidden_s_total."""
    want = float(meta.get("migration_s_total", 0.0)) \
        + float(meta.get("migration_hidden_s_total", 0.0))
    got = attr["span_total_s"]
    return abs(got - want) <= RECONCILE_ATOL + RECONCILE_RTOL * abs(want)


def report(path: str, top: int = 12) -> int:
    obj = load_trace(path)
    try:
        events = validate_chrome_trace(obj)
    except ValueError as e:
        print(f"INVALID trace {path}: {e}", file=sys.stderr)
        return 1
    meta = obj.get("metadata", {}) if isinstance(obj, dict) else {}
    print(f"trace {path}: {len(events)} events"
          + (f", arm={meta.get('arm')}" if meta.get("arm") else "")
          + (f", {meta.get('n_iters')} iters" if meta.get("n_iters")
             else ""))

    rows = span_table(events, top=top)
    if rows:
        print(f"\n{'span':24s} {'count':>6s} {'total ms':>10s} "
              f"{'mean ms':>9s} {'max ms':>9s}")
        for r in rows:
            print(f"{r['name']:24s} {r['count']:6d} {r['total_ms']:10.3f} "
                  f"{r['mean_ms']:9.4f} {r['max_ms']:9.4f}")

    inst = instant_counts(events)
    if inst:
        print("\ninstants: "
              + " ".join(f"{k}={v}" for k, v in inst.items()))

    attr = migration_attribution(events)
    if attr["n_drains"]:
        print(f"\nmigration: {attr['n_drains']} drains, "
              f"{attr['span_total_s'] * 1e3:.3f} ms total span "
              f"({attr['stall_s'] * 1e3:.3f} ms stalled serving, "
              f"{attr['hidden_s'] * 1e3:.3f} ms hidden under compute)")
    if "migration_s_total" in meta:
        want_stall = float(meta["migration_s_total"])
        want_hidden = float(meta.get("migration_hidden_s_total", 0.0))
        ok = reconcile(attr, meta)
        print(f"reconcile vs run totals: spans={attr['span_total_s']:.9f}s "
              f"vs stall+hidden={want_stall + want_hidden:.9f}s -> "
              f"{'OK' if ok else 'MISMATCH'}")
        if not ok:
            return 2
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Chrome-trace JSON from "
                                  "serve_bench --trace-out")
    ap.add_argument("--top", type=int, default=12,
                    help="span rows to print (by total duration)")
    args = ap.parse_args(argv)
    return report(args.trace, top=args.top)


if __name__ == "__main__":
    raise SystemExit(main())
