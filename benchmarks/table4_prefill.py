"""Table 4: ReaLB speedup in the prefill-only (disaggregated) setting —
pure prefill batches (no decode admixture), larger per-iteration token
counts, gate always open.

CSV: model,workload,speedup_prefill_only
"""
from __future__ import annotations

from benchmarks import costmodel as cm
from benchmarks import traces as tr
from repro.configs import ReaLBConfig


def run(iters: int = 300):
    rcfg = ReaLBConfig()
    rows = []
    for mname, g in (("Kimi-VL", cm.KIMI_VL), ("Qwen3-VL", cm.QWEN3_VL)):
        for wname in ("MMMU", "MathVista", "DynaMath"):
            cfg = tr.workload(wname, iters=iters, n_experts=g.n_experts,
                              top_k=g.top_k, tokens_per_iter=16384,
                              decode_frac=0.0)
            base = cm.sim_baseline(cfg, g)
            realb = cm.sim_realb(cfg, g, rcfg)
            rows.append(dict(model=mname, workload=wname,
                             speedup_prefill_only=round(
                                 realb.e2e_speedup(base, g), 3)))
    return rows


def main():
    rows = run()
    cols = list(rows[0])
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))


if __name__ == "__main__":
    main()
