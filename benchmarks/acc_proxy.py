"""Measured accuracy proxy for Table 1/2 ΔAcc columns.

lmms-eval benchmarks can't run on this CPU container, so ΔAcc is measured
as the *quality drift a strategy's quantization inflicts on a real model*:
we train a tiny MMoE (same family as Kimi-VL's backbone: MoE top-k,
shared-expert, multimodal token stream) for a few hundred steps, then
compare BF16 execution against each strategy's precision assignment on
held-out batches:

    Δquality = −100 · (1 − top-1 agreement with BF16)   [≈ ΔAcc direction]
    + logit KL divergence (nats) as the sensitive secondary metric.

The fraction of tokens routed through FP4 experts under each strategy
comes from the cost-model simulation on the matching workload trace, so
speed and accuracy columns describe the *same* execution.

The trained model is cached under experiments/bench_model/.
"""
from __future__ import annotations

import pathlib
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt as ckpt_lib
from repro.configs import ReaLBConfig, TrainConfig, get_config, reduced
from repro.core import quant
from repro.data.pipeline import DataConfig, lm_batch, multimodal_batch
from repro.models import transformer as tf
from repro.optim import adamw

CACHE_DIR = "experiments/bench_model"
_CFG = None


def bench_model_cfg():
    global _CFG
    if _CFG is None:
        _CFG = reduced(get_config("moonshot-v1-16b-a3b"),
                       n_layers=4, d_model=128, vocab_size=512)
    return _CFG


def get_trained_model(steps: int = 150, seed: int = 0):
    """Train (or load) the tiny MMoE used for quality measurement."""
    cfg = bench_model_cfg()
    params = tf.init_model(cfg, jax.random.PRNGKey(seed))
    step = ckpt_lib.latest_step(CACHE_DIR)
    if step is not None and step >= steps:
        _, restored = ckpt_lib.restore(CACHE_DIR, {"params": params})
        return cfg, restored["params"]

    rcfg = ReaLBConfig(enabled=False)
    tcfg = TrainConfig(lr=1e-3, warmup_steps=20, total_steps=steps)
    opt = adamw.init_opt_state(params, tcfg)
    m = jnp.full((1, 1), rcfg.md_init)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=16)

    @jax.jit
    def step_fn(params, opt, m, batch):
        (loss, (m2, _)), g = jax.value_and_grad(tf.train_loss, has_aux=True)(
            params, cfg, rcfg, batch, m)
        params, opt, _ = adamw.adamw_update(params, g, opt, tcfg)
        return params, opt, m2, loss

    for s in range(steps):
        b = multimodal_batch(dc, s)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt, m, loss = step_fn(params, opt, m, batch)
    ckpt_lib.save(CACHE_DIR, steps, {"params": params})
    return cfg, params


def _quantize_expert_slice(params, cfg, rank_mask: np.ndarray, ep: int):
    """Return params with experts of fp4-masked ranks NVFP4 round-tripped
    (weights w4, activations handled by eval-time a4 sim on those ranks is
    approximated by weight-only + activation fake-quant on the ffn input)."""
    e = cfg.moe.num_experts
    e_loc = e // ep
    expert_fp4 = np.repeat(rank_mask.astype(bool), e_loc)         # [E]
    sel = jnp.asarray(expert_fp4)

    def qmap(path_w):
        def f(w):
            # w [nb, E, a, b] stacked expert weights: quantize along axis -2
            wq = quant.fp4_sim(w.swapaxes(-1, -2)).swapaxes(-1, -2)
            m = sel.reshape((1, e) + (1,) * (w.ndim - 2))
            return jnp.where(m, wq, w)
        return f

    new = jax.tree.map(lambda x: x, params)  # shallow copy
    blocks = dict(new["blocks"])
    for lname, lp in blocks.items():
        if "moe" in lp:
            moe = dict(lp["moe"])
            for wname in ("w_gate", "w_up", "w_down"):
                moe[wname] = qmap(wname)(lp["moe"][wname])
            lp = dict(lp)
            lp["moe"] = moe
            blocks[lname] = lp
    new["blocks"] = blocks
    return new


def measure_quality(strategy_rank_frac: float, ep: int = 8,
                    n_eval_batches: int = 8, seed: int = 1,
                    params=None, cfg=None) -> Dict[str, float]:
    """Quality delta when `strategy_rank_frac` of EP ranks run FP4.

    Rank masks are re-drawn per batch (hotspots move), matching ReaLB's
    per-iteration assignment."""
    if params is None:
        cfg, params = get_trained_model()
    rcfg = ReaLBConfig(enabled=False)
    m = jnp.full((1, 1), rcfg.md_init)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=16,
                    seed=seed + 99)
    rng = np.random.default_rng(seed)

    @partial(jax.jit, static_argnames=())
    def logits_of(params, batch):
        res = tf.train_forward(params, cfg, rcfg, batch, m)
        return res.logits

    agree, kl, ce_ref, ce_q = [], [], [], []
    for i in range(n_eval_batches):
        b = multimodal_batch(dc, 10_000 + i)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        n_fp4 = int(round(strategy_rank_frac * ep))
        mask = np.zeros(ep)
        mask[rng.choice(ep, n_fp4, replace=False)] = 1.0
        qparams = _quantize_expert_slice(params, cfg, mask, ep)
        lr = logits_of(params, batch)
        lq = logits_of(qparams, batch)
        pr = jax.nn.log_softmax(lr, -1)
        pq = jax.nn.log_softmax(lq, -1)
        valid = batch["labels"] >= 0
        agree.append(float(jnp.mean(
            (jnp.argmax(lr, -1) == jnp.argmax(lq, -1))[valid])))
        kl.append(float(jnp.sum(jnp.exp(pr) * (pr - pq), -1)[valid].mean()))
        ce_ref.append(float(tf.cross_entropy(lr, batch["labels"])))
        ce_q.append(float(tf.cross_entropy(lq, batch["labels"])))
    return {
        "top1_agreement": float(np.mean(agree)),
        "delta_acc_proxy": -100.0 * (1.0 - float(np.mean(agree))),
        "logit_kl": float(np.mean(kl)),
        "delta_ce": float(np.mean(ce_q) - np.mean(ce_ref)),
    }
