"""End-to-end open-loop serving benchmark: workload → engine → percentiles.

Drives the full request path (arrival process → multimodal prompt
synthesis → modality-aware admission → chunked batched prefill → decode)
with ReaLB live, and reports the paper's serving quantities: TTFT / TPOT
percentiles (overall and split by modality), ``ib_global`` distribution,
and LB-gate / FP4 duty cycles split by phase — batched prefill is where
the gate opens, which the v1 per-request prefill loop never reached.

    PYTHONPATH=src python benchmarks/serve_bench.py \
        --workload MMMU --arrivals bursty

Runs in *virtual time* by default: a seeded arrival stream plus a linear
per-iteration cost model make every latency number reproducible across
hosts (use ``--wall-time`` for real clocks).  ``--record``/``--replay``
pin the exact request stream for policy A/Bs:

    python benchmarks/serve_bench.py --workload MMMU --arrivals bursty \
        --record /tmp/mmmu.jsonl
    python benchmarks/serve_bench.py --replay /tmp/mmmu.jsonl --policy off

``--arm`` selects one of the four placement-comparison arms of the
paper's baseline axis (off / realb / placement / realb+placement) and
implies a virtual EP topology (``--virtual-ep``, default 4) so IB_d,
FP4 duty and migration bytes are meaningful in a single-device
virtual-time run; the plain ``--policy`` flag keeps the original
placement-free behavior.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import List, Optional

import numpy as np

from repro.configs import (PlacementConfig, ReaLBConfig, get_config,
                           reduced)
from repro.models import transformer as tf
from repro.placement import PlacementManager
from repro.serving.engine import Engine
from repro.serving.telemetry import Telemetry
from repro.workloads import (ArrivalConfig, ClosedLoop, IterationCostModel,
                             VirtualClock, arrival_times, load_stream,
                             make_stream, profile, save_stream, stream_stats)
from repro.workloads.multimodal import RequestSpec, synth_request
from repro.workloads.profiles import WORKLOADS

# ReaLBConfig overrides per ablation arm
POLICIES = {
    "realb": {},
    "realb-seq": {"overlap": False},     # serialise quantize after dispatch
    "off": {"enabled": False},           # never compress
}

# the four serving arms of the placement comparison: (policy, placement?)
ARMS = {
    "off": ("off", False),
    "realb": ("realb", False),
    "placement": ("off", True),
    "realb+placement": ("realb", True),
}


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--workload", default="MMMU", choices=sorted(WORKLOADS))
    ap.add_argument("--arrivals", default="poisson",
                    choices=["poisson", "bursty", "diurnal", "closed"])
    ap.add_argument("--policy", default="realb", choices=sorted(POLICIES))
    ap.add_argument("--arm", default=None, choices=sorted(ARMS),
                    help="placement-comparison arm; overrides --policy and "
                         "enables the expert-placement loop for the "
                         "'placement' arms")
    ap.add_argument("--planner", default="least_loaded",
                    choices=["identity", "least_loaded", "modality_aware"])
    ap.add_argument("--replan-every", type=int, default=32,
                    help="engine iterations between placement replans")
    ap.add_argument("--virtual-ep", type=int, default=None,
                    help="virtual EP topology for the policy statistics on "
                         "a single device (default: 4 when --arm is given, "
                         "else off)")
    ap.add_argument("--arch", default="moonshot-v1-16b-a3b")
    ap.add_argument("--preset", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--rate", type=float, default=12.0,
                    help="mean arrivals per (virtual) second")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=512)
    ap.add_argument("--prefill-budget", type=int, default=1024)
    ap.add_argument("--gate-gamma", type=int, default=512,
                    help="LB gate Γ on *real* routed tokens; sized so "
                         "multi-request prefill chunks cross it while "
                         "decode batches stay far below")
    ap.add_argument("--text-reserve", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--wall-time", action="store_true",
                    help="use wall clocks instead of the virtual clock")
    ap.add_argument("--record", default=None, metavar="PATH",
                    help="save the realized request stream to JSONL")
    ap.add_argument("--replay", default=None, metavar="PATH",
                    help="replay a recorded JSONL stream (overrides "
                         "--workload/--arrivals/--requests)")
    ap.add_argument("--json", action="store_true",
                    help="emit one machine-readable JSON summary line")
    return ap.parse_args(argv)


def build_stream(args, vocab_size: int, max_prompt: int
                 ) -> List[RequestSpec]:
    prof = profile(args.workload)
    acfg = ArrivalConfig(kind=args.arrivals, rate=args.rate,
                         n_requests=args.requests, seed=args.seed,
                         concurrency=min(args.slots, args.requests))
    return make_stream(prof, arrival_times(acfg), vocab_size,
                       seed=args.seed + 1, max_prompt=max_prompt)


def resolve_arm(args):
    """Apply --arm to (policy, placement on/off, virtual_ep) in place."""
    use_placement = False
    if args.arm is not None:
        args.policy, use_placement = ARMS[args.arm]
        if args.virtual_ep is None:
            args.virtual_ep = 4
    return use_placement


def serve(args, cfg, params, specs: List[RequestSpec]):
    """Run the open-loop experiment; returns (telemetry, engine, realized
    specs, wall seconds)."""
    use_placement = resolve_arm(args)
    rcfg = ReaLBConfig(gate_gamma=args.gate_gamma, **POLICIES[args.policy])
    manager = None
    if use_placement:
        pcfg = PlacementConfig(planner=args.planner,
                               replan_every=args.replan_every)
        manager = PlacementManager(cfg, pcfg, ep=args.virtual_ep or 4)
    telemetry = Telemetry()
    if args.wall_time:
        # zero the wall clock at run start so it is comparable with the
        # stream's arrival times (seconds from 0) and paces the open loop
        t_start = time.monotonic()
        clock = lambda: time.monotonic() - t_start  # noqa: E731
    else:
        clock = VirtualClock()
    cost = IterationCostModel() if not args.wall_time else None
    eng = Engine(cfg, params, rcfg, max_slots=args.slots,
                 max_len=args.max_len, prefill_budget=args.prefill_budget,
                 text_reserve=args.text_reserve, clock=clock,
                 telemetry=telemetry, cost_model=cost,
                 placement=manager, virtual_ep=args.virtual_ep)

    closed = None
    prof = profile(args.workload)
    spec_rng = np.random.default_rng(args.seed + 2)
    next_uid = len(specs)
    if args.arrivals == "closed" and args.replay is None:
        closed = ClosedLoop(ArrivalConfig(
            kind="closed", rate=args.rate, n_requests=args.requests,
            seed=args.seed, concurrency=min(args.slots, args.requests)))

    pending = sorted(specs, key=lambda s: s.arrival)
    realized: List[RequestSpec] = []
    n_total = args.requests if closed else len(pending)
    n_finished_seen = 0
    t0 = time.monotonic()
    max_prompt = args.max_len - prof.max_new_max - 1
    iters = 0
    while len(eng.scheduler.finished) < n_total:
        iters += 1
        assert iters < 200_000, "serve loop failed to converge"
        if eng.scheduler.idle and not pending:
            break                     # nothing left to do (replay shorter?)
        now = clock()
        while pending and pending[0].arrival <= now:
            spec = pending.pop(0)
            realized.append(spec)
            eng.submit(spec.to_request(d_model=cfg.d_model))
        if eng.scheduler.idle and pending:
            # idle gap: jump the event clock to the next arrival
            if isinstance(clock, VirtualClock):
                clock.advance(pending[0].arrival - now)
            else:
                time.sleep(max(pending[0].arrival - now, 0.0))
            continue
        eng.step()   # the engine advances the virtual clock per forward
        if closed is not None:
            # every completion re-arms one user after a think time
            for req in eng.scheduler.finished[n_finished_seen:]:
                nxt = closed.next_arrival(req.finish_time)
                if nxt is not None:
                    spec = synth_request(prof, next_uid, nxt, spec_rng,
                                         cfg.vocab_size,
                                         max_prompt=max_prompt)
                    next_uid += 1
                    pending.append(spec)
            pending.sort(key=lambda s: s.arrival)
            n_finished_seen = len(eng.scheduler.finished)
    return telemetry, eng, realized, time.monotonic() - t0


def main(argv=None) -> int:
    import jax

    args = parse_args(argv)
    cfg = get_config(args.arch)
    if args.preset == "tiny":
        cfg = reduced(cfg)
    prof = profile(args.workload)
    max_prompt = args.max_len - prof.max_new_max - 1

    if args.replay:
        meta, specs = load_stream(args.replay)
        args.requests = len(specs)
        if args.arrivals == "closed":
            args.arrivals = meta.get("arrivals", "poisson")
        print(f"replaying {len(specs)} requests from {args.replay} "
              f"(meta: {meta})")
    else:
        specs = build_stream(args, cfg.vocab_size, max_prompt)

    resolve_arm(args)     # idempotent; serve() resolves again
    print(f"workload={args.workload} arrivals={args.arrivals} "
          f"policy={args.policy} arch={cfg.name} "
          f"slots={args.slots} budget={args.prefill_budget} "
          f"gate_gamma={args.gate_gamma}"
          + (f" arm={args.arm} planner={args.planner} "
             f"replan_every={args.replan_every} "
             f"virtual_ep={args.virtual_ep}" if args.arm else ""))
    print(f"stream: {stream_stats(specs)}")

    params = tf.init_model(cfg, jax.random.PRNGKey(args.seed))
    telemetry, eng, realized, wall = serve(args, cfg, params, specs)

    if args.record:
        save_stream(args.record, realized,
                    meta=dict(workload=args.workload,
                              arrivals=args.arrivals, seed=args.seed,
                              policy=args.policy))
        print(f"recorded {len(realized)} requests -> {args.record}")

    done = eng.scheduler.finished
    out_toks = sum(len(r.generated) for r in done)
    in_toks = sum(r.prompt_len for r in done)
    s = telemetry.summary()
    s["throughput_tok_per_s"] = (in_toks + out_toks) / max(wall, 1e-9)
    s["wall_s"] = wall
    if args.json:
        print(json.dumps(s, default=float))
        return 0

    def fmt(d):
        return " ".join(f"{k}={v:.4f}" for k, v in d.items()) or "(none)"

    print(f"served {len(done)} requests, {in_toks} prompt + {out_toks} "
          f"generated tokens in {wall:.1f}s wall "
          f"({(in_toks + out_toks) / max(wall, 1e-9):.0f} tok/s), "
          f"{s['n_iters']} iterations")
    print(f"TTFT        {fmt(s['ttft'])}")
    print(f"TTFT vision {fmt(s['ttft_vision'])}")
    print(f"TTFT text   {fmt(s['ttft_text'])}")
    print(f"TPOT        {fmt(s['tpot'])}")
    print(f"IB_global   {fmt(s['ib_global'])}")
    print(f"drop_frac   {fmt(s['drop_frac'])}")
    print(f"gate duty: prefill={s['gate_duty_prefill']:.2f} "
          f"decode={s['gate_duty_decode']:.2f}; "
          f"fp4 duty: all={s['fp4_duty']:.2f} "
          f"prefill={s['fp4_duty_prefill']:.2f}")
    print(f"migration: {s['n_migrations']} events, "
          f"{s['migration_bytes_total'] / 1e6:.2f} MB moved, "
          f"{s['migration_s_total'] * 1e3:.2f} ms charged")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
