"""End-to-end open-loop serving benchmark: workload → engine → percentiles.

Drives the full request path (arrival process → multimodal prompt
synthesis → modality-aware admission → chunked batched prefill → decode)
with ReaLB live, and reports the paper's serving quantities: TTFT / TPOT
percentiles (overall and split by modality), ``ib_global`` distribution,
and LB-gate / FP4 duty cycles split by phase — batched prefill is where
the gate opens, which the v1 per-request prefill loop never reached.

    PYTHONPATH=src python benchmarks/serve_bench.py \
        --workload MMMU --arrivals bursty

Runs in *virtual time* by default: a seeded arrival stream plus a linear
per-iteration cost model make every latency number reproducible across
hosts (use ``--wall-time`` for real clocks).  ``--record``/``--replay``
pin the exact request stream for policy A/Bs:

    python benchmarks/serve_bench.py --workload MMMU --arrivals bursty \
        --record /tmp/mmmu.jsonl
    python benchmarks/serve_bench.py --replay /tmp/mmmu.jsonl --policy off

``--arm`` selects one of the comparison arms of the paper's baseline
axis (off / realb / placement / realb+placement / replicate /
realb+replicate, the ``/L`` per-layer variants that plan one table per
scanned MoE block with layer-diff migration, and the ``/async`` arms
that drain each staged plan as byte-budgeted per-layer slab chunks
overlapped with serving — ``--migrate-async`` /
``--migrate-bytes-per-iter``, stall vs hidden migration seconds split
out in the summary) and implies a virtual
EP topology (``--virtual-ep``, default 4) so IB_d, FP4 duty, token-split
duty and migration bytes are meaningful in a single-device virtual-time
run; the plain ``--policy`` flag keeps the original placement-free
behavior.  ``--arm all`` runs every arm head-to-head on the *same*
realized request stream in one deterministic invocation and prints a
comparison table; ``--json-out BENCH_serve.json`` writes the per-arm
summaries (throughput, TTFT/TPOT percentiles, IB, migration bytes —
per-layer migration bytes included) as a machine-readable CI artifact.

``--scenario kill-rejoin`` drives the elastic serving path: a replicate
arm runs twice on the same realized stream — once healthy, once with a
scripted rank loss at ``--fail-iter`` and a rejoin at ``--rejoin-iter``
(knobs: ``--fail-rank``, and ``--migrate-bytes-per-iter`` as the
recovery chunk budget).  The faulted run re-materializes stranded
singleton experts from a pre-kill checkpoint through the byte-budgeted
migration queue and reports ``recovery_s`` / ``availability`` /
``degraded_iters`` plus post-recovery throughput next to the healthy
arm's:

    python benchmarks/serve_bench.py --scenario kill-rejoin \
        --json-out BENCH_serve.json

Every arm runs with the hot-loop profiler live (FLOP/byte ledger →
``mfu`` / ``roofline_fraction`` / per-phase seconds / costmodel drift in
the summary and ``BENCH_serve.json``); ``--profile-out`` writes the
profile JSON that ``benchmarks/profile_report.py`` summarizes and
reconciles, and ``--xprof-out DIR`` captures a programmatic
``jax.profiler`` device trace with the MoE phases labeled by
``jax.named_scope``.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Optional

import numpy as np

from repro.configs import (PlacementConfig, ReaLBConfig, ReplicationConfig,
                           get_config, reduced)
from repro.models import transformer as tf
from repro.placement import PlacementManager
from repro.replication import ReplicaManager, expand_moe_params
from repro.serving.engine import Engine
from repro.serving.telemetry import Telemetry
from repro.workloads import (ArrivalConfig, ClosedLoop, IterationCostModel,
                             VirtualClock, arrival_times, load_stream,
                             make_stream, profile, save_stream, stream_stats)
from repro.workloads.multimodal import RequestSpec, synth_request
from repro.workloads.profiles import WORKLOADS

# ReaLBConfig overrides per ablation arm
POLICIES = {
    "realb": {},
    "realb-seq": {"overlap": False},     # serialise quantize after dispatch
    "off": {"enabled": False},           # never compress
}

# the serving arms of the load-balancing comparison:
# (policy, expert-layout manager kind, per-layer tables, async migration)
ARMS = {
    "off": ("off", None, False, False),
    "realb": ("realb", None, False, False),
    "placement": ("off", "placement", False, False),
    "realb+placement": ("realb", "placement", False, False),
    "replicate": ("off", "replication", False, False),
    "realb+replicate": ("realb", "replication", False, False),
    # per-layer variants: one table per scanned MoE block, layer-diff
    # migration (changed layers only)
    "placement/L": ("off", "placement", True, False),
    "realb+placement/L": ("realb", "placement", True, False),
    "replicate/L": ("off", "replication", True, False),
    "realb+replicate/L": ("realb", "replication", True, False),
    # async overlapped migration: per-layer slab chunks drain one
    # byte-budgeted batch per iteration; stall vs hidden seconds split
    "placement/L/async": ("off", "placement", True, True),
    "replicate/L/async": ("off", "replication", True, True),
}


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--workload", default="MMMU", choices=sorted(WORKLOADS))
    ap.add_argument("--arrivals", default="poisson",
                    choices=["poisson", "bursty", "diurnal", "closed"])
    ap.add_argument("--policy", default="realb", choices=sorted(POLICIES))
    ap.add_argument("--arm", default=None,
                    choices=sorted(ARMS) + ["all"],
                    help="comparison arm; overrides --policy and enables "
                         "the expert-layout loop for the placement / "
                         "replicate arms.  'all' runs every arm on the "
                         "same realized stream in one deterministic run")
    ap.add_argument("--planner", default="least_loaded",
                    choices=["identity", "least_loaded", "modality_aware"])
    ap.add_argument("--replan-every", type=int, default=32,
                    help="engine iterations between placement replans")
    ap.add_argument("--per-layer", action="store_true",
                    help="per-MoE-layer placement/replication tables "
                         "(one table per scanned block, layer-diff "
                         "migration); the /L arms imply this")
    ap.add_argument("--migrate-async", action="store_true",
                    help="asynchronous overlapped migration: drain a "
                         "staged plan as byte-budgeted per-layer slab "
                         "chunks across serving iterations (each layer's "
                         "table commits as its slab lands) instead of one "
                         "synchronous whole-plan stall; the /async arms "
                         "imply this")
    ap.add_argument("--migrate-bytes-per-iter", type=int, default=0,
                    help="explicit async chunk budget in bytes per "
                         "iteration (0 = derive from the measured "
                         "bytes/s EWMA x recent iteration seconds)")
    ap.add_argument("--decode-replan-every", type=int, default=0,
                    help="decode iterations between decode-regime "
                         "replans, planned from the predictor's decode "
                         "window (0 = prefill cadence only)")
    ap.add_argument("--decode-halflife", type=float, default=8.0,
                    help="decode-window EWMA half-life in decode "
                         "iterations (used when --decode-replan-every "
                         "is set)")
    ap.add_argument("--spare-per-rank", type=int, default=1,
                    help="replica slots per rank beyond E // ranks "
                         "(replicate arms)")
    ap.add_argument("--max-replicas", type=int, default=2,
                    help="replica cap per logical expert (replicate arms)")
    ap.add_argument("--replica-capacity-margin", type=float, default=0.0,
                    help="replica-aware dispatch capacity: shrink "
                         "capacity_factor to margin x the post-split "
                         "predicted peak rank load at each committed "
                         "replan (0 = static capacity_factor)")
    ap.add_argument("--cost-gate", action="store_true",
                    help="gate replans on the analytic cost model: fire "
                         "only when predicted layer-time savings over the "
                         "replan interval exceed the migration time")
    ap.add_argument("--cost-gate-calibrated", action="store_true",
                    help="like --cost-gate, but tokens/iter is calibrated "
                         "from measured engine IterStats instead of the "
                         "static roofline constant")
    ap.add_argument("--scenario", default="steady",
                    choices=["steady", "kill-rejoin"],
                    help="kill-rejoin: run a replicate arm healthy and "
                         "again with a scripted rank loss + rejoin on "
                         "the same stream; emits recovery_s / "
                         "availability / degraded_iters")
    ap.add_argument("--fail-iter", type=int, default=8,
                    help="engine iteration of the scripted rank loss "
                         "(kill-rejoin scenario)")
    ap.add_argument("--rejoin-iter", type=int, default=48,
                    help="engine iteration of the scripted rank rejoin "
                         "(kill-rejoin scenario)")
    ap.add_argument("--fail-rank", type=int, default=1,
                    help="virtual EP rank to kill (kill-rejoin scenario)")
    ap.add_argument("--virtual-ep", type=int, default=None,
                    help="virtual EP topology for the policy statistics on "
                         "a single device (default: 4 when --arm is given, "
                         "else off)")
    ap.add_argument("--arch", default="moonshot-v1-16b-a3b")
    ap.add_argument("--preset", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--rate", type=float, default=12.0,
                    help="mean arrivals per (virtual) second")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=512)
    ap.add_argument("--prefill-budget", type=int, default=1024)
    ap.add_argument("--gate-gamma", type=int, default=512,
                    help="LB gate Γ on *real* routed tokens; sized so "
                         "multi-request prefill chunks cross it while "
                         "decode batches stay far below")
    ap.add_argument("--md-init", type=float, default=None, metavar="M",
                    help="override ReaLB AIMD threshold start m_d "
                         "(default: config's md_init; 0 makes every "
                         "hot vision-heavy rank eligible for FP4 from "
                         "iteration one)")
    ap.add_argument("--no-aimd", action="store_true",
                    help="freeze m_d at its start value (adaptive=False) "
                         "— used by the profiled CI arm to keep the FP4 "
                         "duty cycle deterministic")
    ap.add_argument("--fused", default="auto",
                    choices=["auto", "pallas", "interpret", "jnp"],
                    help="FP4 expert-FFN backend (kernels/ops.py): fused "
                         "Pallas grouped kernel (native / interpret) or "
                         "the jnp oracle; auto = pallas on TPU, jnp on "
                         "CPU")
    ap.add_argument("--text-reserve", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--wall-time", action="store_true",
                    help="use wall clocks instead of the virtual clock")
    ap.add_argument("--record", default=None, metavar="PATH",
                    help="save the realized request stream to JSONL")
    ap.add_argument("--replay", default=None, metavar="PATH",
                    help="replay a recorded JSONL stream (overrides "
                         "--workload/--arrivals/--requests)")
    ap.add_argument("--json", action="store_true",
                    help="emit one machine-readable JSON summary line")
    ap.add_argument("--json-out", default=None, metavar="PATH",
                    help="write per-arm summaries to a JSON file "
                         "(e.g. BENCH_serve.json as a CI artifact)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome-trace/Perfetto JSON of the run's "
                         "spans (iter / admit / forward.* / "
                         "migration.drain / replan.* / elastic.*); also "
                         "attaches the replan audit log.  Deterministic "
                         "under the virtual clock.  Summarize with "
                         "benchmarks/trace_report.py; under --arm all / "
                         "kill-rejoin the trace covers the last "
                         "(faulted) run only")
    ap.add_argument("--audit-out", default=None, metavar="PATH",
                    help="write the replan-decision audit log (one JSON "
                         "event per maybe_replan verdict: cadence, "
                         "warmup, min-gain, cost-gate numbers, must-plan) "
                         "as JSONL")
    ap.add_argument("--log-every", type=int, default=0, metavar="N",
                    help="print one structured JSONL log line every N "
                         "serving iterations (iter, phase, tokens, "
                         "ib_global, fp4_ranks, mfu, per-phase seconds, "
                         "migration stall/hidden, unroutable) for "
                         "long-run debugging without a trace viewer")
    ap.add_argument("--profile-out", default=None, metavar="PATH",
                    help="write the hot-loop profiler's phase/FLOP/drift "
                         "JSON (schema repro.profile.v1); summarize and "
                         "reconcile with benchmarks/profile_report.py. "
                         "Under --arm all / kill-rejoin the profile "
                         "covers the last run only (like --trace-out)")
    ap.add_argument("--sentinel", action="store_true",
                    help="arm the repro.analysis runtime sentinel for "
                         "the run: guard the hot loop against "
                         "unsanctioned device->host syncs and count jit "
                         "compiles per engine entry point")
    ap.add_argument("--sentinel-out", default=None, metavar="PATH",
                    help="write the sentinel report JSON (implies "
                         "--sentinel)")
    ap.add_argument("--xprof-out", default=None, metavar="DIR",
                    help="capture a programmatic jax.profiler device "
                         "trace of the serve loop into DIR (open with "
                         "xprof/tensorboard); the jax.named_scope phase "
                         "annotations in core/ep_moe.py label the MoE "
                         "stages in the timeline")
    return ap.parse_args(argv)


def build_stream(args, vocab_size: int, max_prompt: int
                 ) -> List[RequestSpec]:
    prof = profile(args.workload)
    acfg = ArrivalConfig(kind=args.arrivals, rate=args.rate,
                         n_requests=args.requests, seed=args.seed,
                         concurrency=min(args.slots, args.requests))
    return make_stream(prof, arrival_times(acfg), vocab_size,
                       seed=args.seed + 1, max_prompt=max_prompt)


def resolve_arm(args):
    """Apply --arm to (policy, manager kind, per-layer, async migration,
    virtual_ep) in place; returns the manager kind."""
    kind = None
    if args.arm is not None and args.arm != "all":
        args.policy, kind, per_layer, migrate_async = ARMS[args.arm]
        args.per_layer = args.per_layer or per_layer
        args.migrate_async = args.migrate_async or migrate_async
        if args.virtual_ep is None:
            args.virtual_ep = 4
    return kind


def make_cost_gate(args, cfg, ep: int):
    """An analytic-cost-model replan gate for this model's MoE geometry
    (``--cost-gate-calibrated`` swaps the static tokens/iter constant for
    a window of measured engine iterations)."""
    try:
        from benchmarks import costmodel as cm
    except ImportError:     # run as `python benchmarks/serve_bench.py`:
        import pathlib      # sys.path[0] is benchmarks/, not the repo root
        import sys
        sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
        from benchmarks import costmodel as cm
    n_moe = max(sum(1 for f in cfg.ffn_kinds() if f == "moe"), 1)
    geom = cm.MoEGeometry(cfg.name, cfg.d_model, cfg.moe.d_ff,
                          cfg.moe.num_experts, cfg.moe.top_k, n_moe)
    if args.cost_gate_calibrated:
        return cm.CalibratedReplanCostGate(
            geom, ep, horizon_iters=args.replan_every,
            default_tokens=float(args.prefill_budget))
    return cm.ReplanCostGate(geom, ep, horizon_iters=args.replan_every,
                             tokens_per_iter=float(args.prefill_budget))


def serve(args, cfg, params, specs: List[RequestSpec],
          inject_faults: bool = False):
    """Run the open-loop experiment; returns (telemetry, engine, realized
    specs, wall seconds).  ``inject_faults`` arms the kill-rejoin
    scenario: a pre-kill checkpoint, an :class:`ElasticCoordinator` over
    the replica manager and a scripted :class:`FaultInjector`."""
    kind = resolve_arm(args)
    from repro.kernels import ops as kops
    kops.set_ffn_backend(args.fused)
    pol = dict(POLICIES[args.policy])
    if args.md_init is not None:
        pol["md_init"] = args.md_init
    if args.no_aimd:
        pol["adaptive"] = False
    rcfg = ReaLBConfig(gate_gamma=args.gate_gamma, **pol)
    manager = None
    vep = args.virtual_ep or 4
    gate = make_cost_gate(args, cfg, vep) \
        if ((args.cost_gate or args.cost_gate_calibrated)
            and kind is not None) else None
    decode_hl = args.decode_halflife if args.decode_replan_every else 0.0
    if kind == "placement":
        pcfg = PlacementConfig(planner=args.planner,
                               replan_every=args.replan_every,
                               per_layer=args.per_layer,
                               decode_halflife=decode_hl,
                               decode_replan_every=args.decode_replan_every)
        manager = PlacementManager(cfg, pcfg, ep=vep, cost_gate=gate)
    elif kind == "replication":
        rpcfg = ReplicationConfig(replan_every=args.replan_every,
                                  spare_per_rank=args.spare_per_rank,
                                  max_replicas=args.max_replicas,
                                  per_layer=args.per_layer,
                                  decode_halflife=decode_hl,
                                  decode_replan_every=args.decode_replan_every)
        manager = ReplicaManager(cfg, rpcfg, ep=vep, cost_gate=gate)
        # lay the logical expert rows out into the replica slot space
        # (each scanned block by its own layer's set when per-layer)
        params = expand_moe_params(params, manager.rsets)
    if inject_faults and kind != "replication":
        raise SystemExit("--scenario kill-rejoin needs a replicate arm "
                         "(replicas are the availability mechanism); "
                         f"got arm={args.arm!r}")
    telemetry = Telemetry()
    # hot-loop profiler: FLOP/byte ledger + per-phase attribution +
    # costmodel drift, on every arm; it shares the telemetry registry so
    # mfu / roofline_fraction / phase seconds surface in summary() and
    # every arm's BENCH_serve.json
    profiler = None
    if cfg.moe is not None:
        from repro.obs import FlopByteLedger, Profiler
        profiler = Profiler(FlopByteLedger(cfg, ep=vep,
                                           fused=kops.ffn_fused()),
                            registry=telemetry.registry)
    if args.wall_time:
        # zero the wall clock at run start so it is comparable with the
        # stream's arrival times (seconds from 0) and paces the open loop
        t_start = time.monotonic()
        clock = lambda: time.monotonic() - t_start  # noqa: E731
    else:
        clock = VirtualClock()
    cost = IterationCostModel() if not args.wall_time else None
    # observability (opt-in): spans on the run clock — deterministic
    # under the virtual clock — and the replan-decision audit log
    trace_out = getattr(args, "trace_out", None)
    audit_out = getattr(args, "audit_out", None)
    tracer = None
    if trace_out:
        from repro.obs import Tracer
        tracer = Tracer(clock=clock)
    if manager is not None and (trace_out or audit_out):
        from repro.obs import ReplanAudit
        manager.audit = ReplanAudit()
    elastic = injector = None
    if inject_faults:
        import tempfile

        from repro.checkpoint import ckpt as ckpt_lib
        from repro.runtime.fault_tolerance import FaultInjector
        from repro.serving.elastic import ElasticCoordinator

        # the re-materialization source for singleton experts stranded
        # by the kill: the expanded slot-space params plus the manager's
        # replica tables, saved before any fault
        ckpt_dir = tempfile.mkdtemp(prefix="serve_bench_elastic_")
        ckpt_lib.save(ckpt_dir, 0,
                      {"serving": {"params": params},
                       manager.ckpt_group: manager.state_dict()})
        elastic = ElasticCoordinator(manager, ckpt_dir=ckpt_dir,
                                     clock=clock, telemetry=telemetry)
        injector = FaultInjector([(args.fail_iter, "fail", args.fail_rank),
                                  (args.rejoin_iter, "rejoin",
                                   args.fail_rank)])
    sentinel = None
    if getattr(args, "sentinel", False) or getattr(args, "sentinel_out",
                                                   None):
        from repro.analysis.sentinel import Sentinel
        sentinel = Sentinel()
        sentinel.arm()
    eng = Engine(cfg, params, rcfg, max_slots=args.slots,
                 max_len=args.max_len, prefill_budget=args.prefill_budget,
                 text_reserve=args.text_reserve, clock=clock,
                 telemetry=telemetry, cost_model=cost,
                 placement=manager, virtual_ep=args.virtual_ep,
                 capacity_margin=(args.replica_capacity_margin or None)
                 if kind == "replication" else None,
                 migrate_async=args.migrate_async,
                 migrate_bytes_per_iter=args.migrate_bytes_per_iter
                 or None,
                 elastic=elastic, fault_injector=injector, tracer=tracer,
                 profiler=profiler, sentinel=sentinel)

    xprof_out = getattr(args, "xprof_out", None)
    if xprof_out:
        import jax
        jax.profiler.start_trace(xprof_out)

    closed = None
    prof = profile(args.workload)
    spec_rng = np.random.default_rng(args.seed + 2)
    next_uid = len(specs)
    if args.arrivals == "closed" and args.replay is None:
        closed = ClosedLoop(ArrivalConfig(
            kind="closed", rate=args.rate, n_requests=args.requests,
            seed=args.seed, concurrency=min(args.slots, args.requests)))

    pending = sorted(specs, key=lambda s: s.arrival)
    realized: List[RequestSpec] = []
    n_total = args.requests if closed else len(pending)
    n_finished_seen = 0
    t0 = time.monotonic()
    max_prompt = args.max_len - prof.max_new_max - 1
    iters = 0
    while len(eng.scheduler.finished) < n_total:
        iters += 1
        assert iters < 200_000, "serve loop failed to converge"
        if eng.scheduler.idle and not pending:
            break                     # nothing left to do (replay shorter?)
        now = clock()
        while pending and pending[0].arrival <= now:
            spec = pending.pop(0)
            realized.append(spec)
            eng.submit(spec.to_request(d_model=cfg.d_model))
        if eng.scheduler.idle and pending:
            # idle gap: jump the event clock to the next arrival
            if isinstance(clock, VirtualClock):
                clock.advance(pending[0].arrival - now)
            else:
                time.sleep(max(pending[0].arrival - now, 0.0))
            continue
        eng.step()   # the engine advances the virtual clock per forward
        log_every = getattr(args, "log_every", 0)
        if log_every and iters % log_every == 0 and eng.stats:
            print(json.dumps(iter_log_record(eng, iters), default=float))
        if closed is not None:
            # every completion re-arms one user after a think time
            for req in eng.scheduler.finished[n_finished_seen:]:
                nxt = closed.next_arrival(req.finish_time)
                if nxt is not None:
                    spec = synth_request(prof, next_uid, nxt, spec_rng,
                                         cfg.vocab_size,
                                         max_prompt=max_prompt)
                    next_uid += 1
                    pending.append(spec)
            pending.sort(key=lambda s: s.arrival)
            n_finished_seen = len(eng.scheduler.finished)
    # finish any in-flight async chunk queue so the migration accounting
    # is complete and the engine is left in a checkpointable state
    eng.drain_migrations()
    if xprof_out:
        import jax
        jax.profiler.stop_trace()
        print(f"wrote xprof device trace -> {xprof_out}")
    profile_out = getattr(args, "profile_out", None)
    if profile_out and profiler is not None:
        from repro.kernels import ops as kops
        profiler.write(profile_out, metadata=dict(
            arm=args.arm or args.policy, arch=cfg.name,
            workload=args.workload, virtual_time=not args.wall_time,
            ffn_backend=kops.ffn_backend(), fused=kops.ffn_fused(),
            n_iters=int(telemetry.n_iters)))
        print(f"wrote profile ({profiler.n_iters} iters) -> {profile_out}")
    if tracer is not None:
        # the run totals travel with the trace so trace_report.py can
        # reconcile summed migration.drain span durations against them
        # without the JSON artifact
        tracer.write(trace_out, metadata=dict(
            arm=args.arm or args.policy,
            n_iters=int(telemetry.n_iters),
            virtual_time=not args.wall_time,
            migration_s_total=float(eng.migration_stall_s),
            migration_hidden_s_total=float(eng.migration_hidden_s),
            migration_bytes_total=int(eng.migration_bytes_moved)))
        print(f"wrote {len(tracer)} trace events -> {trace_out}")
    if audit_out and manager is not None \
            and getattr(manager, "audit", None) is not None:
        manager.audit.to_jsonl(audit_out)
        print(f"wrote {len(manager.audit)} replan decisions -> {audit_out}")
    if sentinel is not None:
        sentinel.disarm()
        rep = sentinel.report()
        print(f"sentinel: ok={rep['ok']} "
              f"syncs={len(rep['violations'])} "
              f"compiles={rep['compile_counts']} "
              f"rebuilds={len(rep['rebuilds'])}")
        sent_out = getattr(args, "sentinel_out", None)
        if sent_out:
            with open(sent_out, "w") as f:
                json.dump(rep, f, indent=2)
            print(f"wrote sentinel report -> {sent_out}")
    return telemetry, eng, realized, time.monotonic() - t0


def iter_log_record(eng: Engine, it: int) -> Dict:
    """One greppable JSONL log line from the engine's last recorded
    iteration (``--log-every``): long-run debugging without a trace
    viewer."""
    st = eng.stats[-1]
    rec = dict(iter=it, t=round(float(st.t_wall), 6), phase=st.phase,
               n_active=int(st.n_active), tokens=int(st.tokens),
               ib_global=round(float(st.ib_global), 4),
               fp4_ranks=float(st.fp4_ranks),
               gate_open=float(st.gate_open),
               migration_s=float(st.migration_s),
               migration_hidden_s=float(st.migration_hidden_s),
               n_unroutable=int(st.n_unroutable))
    prof = eng.profiler
    if prof.enabled and getattr(prof, "last", None) is not None:
        rec["mfu"] = round(prof.mfu(), 6)
        rec["time_scale"] = round(prof.time_scale(), 4)
        rec["phase_s"] = {ph: round(v, 6)
                          for ph, v in prof.phase_seconds().items()}
    return rec


def summarize_run(telemetry: Telemetry, eng: Engine, wall: float) -> Dict:
    """Flat per-arm summary (table / JSON-artifact friendly)."""
    done = eng.scheduler.finished
    out_toks = sum(len(r.generated) for r in done)
    in_toks = sum(r.prompt_len for r in done)
    s = telemetry.summary()
    s["n_requests_served"] = len(done)
    s["prompt_tokens"] = in_toks
    s["generated_tokens"] = out_toks
    s["throughput_tok_per_s"] = (in_toks + out_toks) / max(wall, 1e-9)
    s["wall_s"] = wall
    # engine-side cumulative accounting covers tail drains (e.g. the
    # post-loop drain_migrations()) that never reached a recorded
    # iteration — telemetry only sees IterStats, so its totals would
    # under-count async arms and disagree with migration_bytes_per_layer
    s["migration_bytes_total"] = int(eng.migration_bytes_moved)
    s["migration_stall_s"] = eng.migration_stall_s
    s["migration_s_total"] = eng.migration_stall_s
    s["migration_hidden_s"] = eng.migration_hidden_s
    mgr = eng._placement
    if mgr is not None:
        # per-layer migration traffic: [n_tables] cumulative bytes, so
        # the CI perf trajectory captures WHERE the migration cost lands
        # (changed layers only under layer-diff plans); byte counts are
        # integral end-to-end
        s["n_tables"] = int(getattr(mgr, "n_tables", 1))
        # disambiguated counters: telemetry's n_migrations counts
        # ITERATIONS that carried migration traffic (chunk batches under
        # async drain), the manager's counts COMMITTED PLANS.  The legacy
        # "n_migrations" key keeps its historical manager-side meaning.
        s["n_migrations"] = int(mgr.n_migrations)
        s["n_plans_committed"] = int(mgr.n_migrations)
        s["n_migration_iters"] = int(telemetry.n_migrations)
        if getattr(mgr, "audit", None) is not None:
            s["replan_decisions"] = mgr.audit.counts()
        s["migration_bytes_per_layer"] = [
            int(b) for b in getattr(mgr, "migrated_bytes_per_layer", [])]
        s["migration_bw_measured"] = float(mgr.bandwidth) \
            if mgr.bandwidth.calibrated else None
    return s


def windowed_tok_per_s(eng: Engine, t0: float) -> Optional[float]:
    """Throughput over the recorded iterations strictly after engine
    time ``t0`` — the post-recovery window when ``t0`` is the recovery
    stamp (both arms share the clock model, so the same window is
    comparable across the healthy and faulted runs)."""
    stats = [s for s in eng.stats if s.t_wall > t0]
    if len(stats) < 2:
        return None
    return sum(s.tokens for s in stats) / max(stats[-1].t_wall - t0, 1e-9)


def write_json_out(args, results: Dict[str, Dict]) -> None:
    payload = {
        "meta": dict(workload=args.workload, arrivals=args.arrivals,
                     arch=args.arch, preset=args.preset,
                     requests=args.requests, rate=args.rate,
                     seed=args.seed, slots=args.slots,
                     prefill_budget=args.prefill_budget,
                     gate_gamma=args.gate_gamma, planner=args.planner,
                     replan_every=args.replan_every,
                     virtual_ep=args.virtual_ep or 4,
                     spare_per_rank=args.spare_per_rank,
                     max_replicas=args.max_replicas,
                     per_layer=args.per_layer,
                     migrate_async=args.migrate_async,
                     migrate_bytes_per_iter=args.migrate_bytes_per_iter,
                     decode_replan_every=args.decode_replan_every,
                     replica_capacity_margin=args.replica_capacity_margin,
                     cost_gate=args.cost_gate,
                     cost_gate_calibrated=args.cost_gate_calibrated,
                     scenario=args.scenario, fail_iter=args.fail_iter,
                     rejoin_iter=args.rejoin_iter,
                     fail_rank=args.fail_rank,
                     replay=args.replay),
        "arms": results,
    }
    with open(args.json_out, "w") as f:
        json.dump(payload, f, indent=2, default=float)
    print(f"wrote {len(results)} arm summar"
          f"{'ies' if len(results) != 1 else 'y'} -> {args.json_out}")


def print_comparison(results: Dict[str, Dict]) -> None:
    def q(d, k, sub, default=float("nan")):
        v = d.get(k, {})
        return v.get(sub, default) if isinstance(v, dict) else default

    print(f"\n{'arm':18s} {'tok/s':>8s} {'ttft p50':>9s} {'ttft p99':>9s} "
          f"{'tpot p50':>9s} {'IB mean':>8s} {'IB p99':>7s} {'fp4':>5s} "
          f"{'split':>6s} {'mig MB':>7s} {'stall ms':>9s} {'hidden ms':>9s}")
    for name, s in results.items():
        print(f"{name:18s} {s['throughput_tok_per_s']:8.0f} "
              f"{q(s, 'ttft', 'p50'):9.4f} {q(s, 'ttft', 'p99'):9.4f} "
              f"{q(s, 'tpot', 'p50'):9.4f} "
              f"{q(s, 'ib_global', 'mean'):8.3f} "
              f"{q(s, 'ib_global', 'p99'):7.3f} "
              f"{s['fp4_duty']:5.2f} {s['split_duty']:6.2f} "
              f"{s['migration_bytes_total'] / 1e6:7.2f} "
              f"{s['migration_stall_s'] * 1e3:9.2f} "
              f"{s['migration_hidden_s'] * 1e3:9.2f}")


def main(argv=None) -> int:
    import jax

    args = parse_args(argv)
    cfg = get_config(args.arch)
    if args.preset == "tiny":
        cfg = reduced(cfg)
    prof = profile(args.workload)
    max_prompt = args.max_len - prof.max_new_max - 1

    if args.replay:
        meta, specs = load_stream(args.replay)
        args.requests = len(specs)
        if args.arrivals == "closed":
            args.arrivals = meta.get("arrivals", "poisson")
        print(f"replaying {len(specs)} requests from {args.replay} "
              f"(meta: {meta})")
    else:
        specs = build_stream(args, cfg.vocab_size, max_prompt)

    params = tf.init_model(cfg, jax.random.PRNGKey(args.seed))

    if args.scenario == "kill-rejoin":
        if args.arm == "all":
            raise SystemExit("--scenario kill-rejoin takes one replicate "
                             "arm, not 'all'")
        if args.arm is None:
            args.arm = "replicate/L/async"
        if ARMS[args.arm][1] != "replication":
            raise SystemExit("--scenario kill-rejoin needs a replicate "
                             f"arm; got arm={args.arm!r}")
        if args.migrate_bytes_per_iter == 0:
            # small per-iteration chunk budget so recovery visibly
            # streams across iterations (one layer slab per drain batch)
            # instead of landing whole inside the kill iteration
            args.migrate_bytes_per_iter = 4096
        resolve_arm(args)        # pin meta before the per-run copies
        print(f"kill-rejoin scenario: arm={args.arm} "
              f"fail_rank={args.fail_rank} fail_iter={args.fail_iter} "
              f"rejoin_iter={args.rejoin_iter} "
              f"budget={args.migrate_bytes_per_iter}B/iter")
        print(f"stream: {stream_stats(specs)}")
        results: Dict[str, Dict] = {}
        healthy_args = argparse.Namespace(**vars(args))
        # the trace/audit artifacts cover the faulted run (the one with
        # elastic events worth inspecting), not the healthy baseline
        healthy_args.trace_out = healthy_args.audit_out = None
        telemetry, eng, _, wall = serve(healthy_args, cfg, params, specs)
        results["healthy"] = summarize_run(telemetry, eng, wall)
        telemetry2, eng2, _, wall2 = serve(
            argparse.Namespace(**vars(args)), cfg, params, specs,
            inject_faults=True)
        s2 = summarize_run(telemetry2, eng2, wall2)
        co = eng2._elastic
        s2["elastic_events"] = [dict(e) for e in co.events]
        rec = [e for e in co.events if e["kind"] == "recovered"]
        t_rec = rec[-1]["t"] if rec else None
        if t_rec is not None:
            s2["post_recovery_tok_per_s"] = windowed_tok_per_s(eng2, t_rec)
            results["healthy"]["post_recovery_tok_per_s"] = \
                windowed_tok_per_s(eng, t_rec)
        results["kill-rejoin"] = s2
        print_comparison(results)
        print(f"\nelastic: recovery_s={s2.get('recovery_s')} "
              f"availability={s2.get('availability', 1.0):.4f} "
              f"degraded_iters={s2.get('degraded_iters')} "
              f"lost_tokens={s2.get('lost_tokens_total', 0.0):.0f} "
              f"events={[e['kind'] for e in co.events]}")
        healthy_post = results["healthy"].get("post_recovery_tok_per_s")
        if s2.get("post_recovery_tok_per_s") and healthy_post:
            print(f"post-recovery throughput: "
                  f"{s2['post_recovery_tok_per_s']:.0f} tok/s vs healthy "
                  f"{healthy_post:.0f} tok/s "
                  f"({s2['post_recovery_tok_per_s'] / healthy_post:.3f}x)")
        if args.json_out:
            write_json_out(args, results)
        if args.json:
            print(json.dumps(results, default=float))
        return 0

    if args.arm == "all":
        # every arm head-to-head on the same realized stream, one
        # deterministic invocation (shared logical params, fresh engine
        # state per arm; migration gathers never mutate the shared tree)
        if args.virtual_ep is None:
            args.virtual_ep = 4
        print(f"comparing {len(ARMS)} arms: workload={args.workload} "
              f"arrivals={args.arrivals} arch={cfg.name} "
              f"requests={len(specs)} virtual_ep={args.virtual_ep}")
        print(f"stream: {stream_stats(specs)}")
        results: Dict[str, Dict] = {}
        realized = specs
        for name in ARMS:
            sub = argparse.Namespace(**vars(args))
            # per-layer / async are the arm's own properties here: a
            # sticky --per-layer or --migrate-async would silently turn
            # the baseline arms into mislabeled duplicates of the /L and
            # /async arms
            sub.arm, sub.record = name, None
            sub.per_layer, sub.migrate_async = False, False
            telemetry, eng, realized, wall = serve(sub, cfg, params, specs)
            results[name] = summarize_run(telemetry, eng, wall)
            print(f"  {name}: {results[name]['n_requests_served']} served, "
                  f"{results[name]['throughput_tok_per_s']:.0f} tok/s, "
                  f"{wall:.1f}s wall")
        if args.record:
            save_stream(args.record, realized,
                        meta=dict(workload=args.workload,
                                  arrivals=args.arrivals, seed=args.seed,
                                  policy="all"))
            print(f"recorded {len(realized)} requests -> {args.record}")
        print_comparison(results)
        if args.json_out:
            write_json_out(args, results)
        if args.json:
            print(json.dumps(results, default=float))
        return 0

    resolve_arm(args)     # idempotent; serve() resolves again
    print(f"workload={args.workload} arrivals={args.arrivals} "
          f"policy={args.policy} arch={cfg.name} "
          f"slots={args.slots} budget={args.prefill_budget} "
          f"gate_gamma={args.gate_gamma}"
          + (f" arm={args.arm} planner={args.planner} "
             f"replan_every={args.replan_every} "
             f"virtual_ep={args.virtual_ep}" if args.arm else ""))
    print(f"stream: {stream_stats(specs)}")

    telemetry, eng, realized, wall = serve(args, cfg, params, specs)

    if args.record:
        save_stream(args.record, realized,
                    meta=dict(workload=args.workload,
                              arrivals=args.arrivals, seed=args.seed,
                              policy=args.policy))
        print(f"recorded {len(realized)} requests -> {args.record}")

    s = summarize_run(telemetry, eng, wall)
    if args.json_out:
        write_json_out(args, {args.arm or args.policy: s})
    if args.json:
        print(json.dumps(s, default=float))
        return 0

    def fmt(d):
        return " ".join(f"{k}={v:.4f}" for k, v in d.items()) or "(none)"

    print(f"served {s['n_requests_served']} requests, "
          f"{s['prompt_tokens']} prompt + {s['generated_tokens']} "
          f"generated tokens in {wall:.1f}s wall "
          f"({s['throughput_tok_per_s']:.0f} tok/s), "
          f"{s['n_iters']} iterations")
    print(f"TTFT        {fmt(s['ttft'])}")
    print(f"TTFT vision {fmt(s['ttft_vision'])}")
    print(f"TTFT text   {fmt(s['ttft_text'])}")
    print(f"TPOT        {fmt(s['tpot'])}")
    print(f"IB_global   {fmt(s['ib_global'])}")
    print(f"drop_frac   {fmt(s['drop_frac'])}")
    print(f"gate duty: prefill={s['gate_duty_prefill']:.2f} "
          f"decode={s['gate_duty_decode']:.2f}; "
          f"fp4 duty: all={s['fp4_duty']:.2f} "
          f"prefill={s['fp4_duty_prefill']:.2f}; "
          f"split duty: {s['split_duty']:.2f}")
    print(f"migration: {s['n_migrations']} events, "
          f"{s['migration_bytes_total'] / 1e6:.2f} MB moved, "
          f"{s['migration_stall_s'] * 1e3:.2f} ms stalled, "
          f"{s['migration_hidden_s'] * 1e3:.2f} ms hidden")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
