"""Synthetic MMoE routing traces with the paper's dynamics (Fig 2).

Generates per-iteration (expert-load, vision-load) matrices for an EP
group, calibrated to the paper's observations:

* hot expert 2–12× the mean expert load, hot device 2–3× the mean,
* vision tokens dominate (large-batch prefill) with per-device vision
  ratios anywhere between <50% and >90%,
* hot spots drift: slow random-walk popularity + abrupt re-permutations
  every few hundred iterations (what defeats sliding-window predictors).

The trace is the common input to every strategy simulator so comparisons
are exact (same randomness, different policy).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    name: str = "MMMU"
    n_experts: int = 64
    top_k: int = 6
    ep: int = 8
    iters: int = 1200
    tokens_per_iter: int = 8192       # prefill-dominated batches
    vision_frac_mean: float = 0.7     # workload modality profile
    vision_frac_std: float = 0.15
    zipf_a: float = 1.15              # routing skew severity
    drift_sigma: float = 0.04         # per-iter popularity random walk
    jump_every: int = 250             # abrupt hot-spot re-permutation
    decode_frac: float = 0.08         # small decode admixture (colocated)
    seed: int = 0


# per-benchmark workload profiles (modality mix & dynamics differ) — the
# calibration lives in repro.workloads.profiles, shared with the
# request-level generator so trace-driven simulations and end-to-end
# serving runs of one named workload agree; re-exported here for the
# existing benchmark scripts.
from repro.workloads.profiles import WORKLOADS  # noqa: E402


def workload(name: str, **overrides) -> TraceConfig:
    base = WORKLOADS[name].copy()
    base.update(overrides)
    return TraceConfig(name=name, **base)


@dataclasses.dataclass
class TraceStep:
    it: int
    expert_load: np.ndarray    # [E] token-expert assignments this iter
    expert_vis: np.ndarray     # [E] vision assignments among them
    tokens: int                # total tokens this iteration


def generate(cfg: TraceConfig) -> Iterator[TraceStep]:
    rng = np.random.default_rng(cfg.seed)
    e = cfg.n_experts
    # text & vision expert-affinity logits, random-walked + re-permuted
    base = -cfg.zipf_a * np.log(np.arange(1, e + 1))
    text_logit = rng.permutation(base).astype(np.float64)
    vis_logit = rng.permutation(base).astype(np.float64)
    for it in range(cfg.iters):
        if cfg.jump_every and it > 0 and it % cfg.jump_every == 0:
            # abrupt hot-spot shift: re-permute the top of one modality
            which = rng.random() < 0.6
            tgt = vis_logit if which else text_logit
            hot = np.argsort(tgt)[-8:]
            tgt[hot] = tgt[rng.permutation(hot)]
        text_logit += rng.normal(0, cfg.drift_sigma, e)
        vis_logit += rng.normal(0, cfg.drift_sigma, e)

        vf = np.clip(rng.normal(cfg.vision_frac_mean, cfg.vision_frac_std),
                     0.05, 0.95)
        tokens = cfg.tokens_per_iter
        n_vis = int(tokens * vf)
        n_txt = tokens - n_vis

        def route(n_tok, logit):
            if n_tok <= 0:
                return np.zeros(e, np.int64)
            p = np.exp(logit - logit.max())
            p /= p.sum()
            # top_k routing ≈ k draws per token from the popularity dist
            return rng.multinomial(n_tok * cfg.top_k, p)

        lv = route(n_vis, vis_logit)
        lt = route(n_txt, text_logit)
        yield TraceStep(it, (lv + lt).astype(np.float64),
                        lv.astype(np.float64), tokens)


def rank_loads(step: TraceStep, placement: np.ndarray, ep: int
               ) -> Tuple[np.ndarray, np.ndarray]:
    """Aggregate expert loads onto EP ranks. placement[e] = owning rank;
    replicated experts use fractional ownership rows (see eplb_sim)."""
    load = np.zeros(ep)
    vis = np.zeros(ep)
    if placement.ndim == 1:
        for e_id, r in enumerate(placement):
            load[r] += step.expert_load[e_id]
            vis[r] += step.expert_vis[e_id]
    else:  # [E, ep] fractional assignment matrix (expert replication)
        load = step.expert_load @ placement
        vis = step.expert_vis @ placement
    return load, vis


def default_placement(n_experts: int, ep: int) -> np.ndarray:
    return (np.arange(n_experts) // (n_experts // ep)).astype(np.int64)


def trace_stats(cfg: TraceConfig) -> Dict[str, float]:
    """Fig-2 style summary statistics for a trace."""
    place = default_placement(cfg.n_experts, cfg.ep)
    emax, dmax, vlo, vhi, flips = [], [], [], [], 0
    prev_hot = -1
    for step in generate(cfg):
        el = step.expert_load
        emax.append(el.max() / max(el.mean(), 1e-9))
        load, vis = rank_loads(step, place, cfg.ep)
        dmax.append(load.max() / max(load.mean(), 1e-9))
        r = vis / np.maximum(load, 1)
        vlo.append(r.min())
        vhi.append(r.max())
        hot = int(np.argmax(load))
        if hot != prev_hot and prev_hot >= 0:
            flips += 1
        prev_hot = hot
    return {
        "expert_imb_mean": float(np.mean(emax)),
        "expert_imb_p95": float(np.percentile(emax, 95)),
        "device_imb_mean": float(np.mean(dmax)),
        "device_imb_p95": float(np.percentile(dmax, 95)),
        "vision_ratio_min_mean": float(np.mean(vlo)),
        "vision_ratio_max_mean": float(np.mean(vhi)),
        "hot_device_flips_per_100it": 100.0 * flips / cfg.iters,
    }
