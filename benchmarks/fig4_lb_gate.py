"""Fig 4: the LB-gate regime — GEMM vs non-GEMM share of the MoE layer as
batch grows, and the net effect of forcing ReaLB on below/above Γ.

CSV: tokens_per_rank,gemm_frac,nongemm_frac,realb_gain_pct,gate_open
"""
from __future__ import annotations

import numpy as np

from benchmarks import costmodel as cm
from repro.configs import ReaLBConfig


def run(g=cm.KIMI_VL, ep: int = 8):
    rcfg = ReaLBConfig()
    rows = []
    for tpr in (16, 64, 256, 512, 1024, 2048, 4096, 8192, 16384):
        # a mildly-imbalanced instantaneous load (hot rank = 2x mean)
        load = np.full(ep, float(tpr))
        load[0] *= 2.0
        tokens = load.sum() / g.top_k
        gemm = cm.expert_gemm_time(load[0], g, ep, False)
        nong = cm.nongemm_time(load[0], g)
        t_base, _ = cm.moe_layer_time(load, np.zeros(ep), g, ep, tokens)
        fp4 = np.zeros(ep)
        fp4[0] = 1.0   # ReaLB compresses the hot rank
        t_realb, _ = cm.moe_layer_time(load, fp4, g, ep, tokens)
        gate_open = tokens * g.top_k > rcfg.gate_gamma
        rows.append(dict(
            tokens_per_rank=tpr,
            gemm_frac=round(gemm / (gemm + nong), 3),
            nongemm_frac=round(nong / (gemm + nong), 3),
            realb_gain_pct=round(100 * (1 - t_realb / t_base), 2),
            gate_open=int(gate_open)))
    return rows


def main():
    rows = run()
    cols = list(rows[0])
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))


if __name__ == "__main__":
    main()
