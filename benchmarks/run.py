"""Benchmark harness: one function per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run`` runs everything and prints one
CSV block per experiment, each prefixed by ``== <name> ==``.  A final
``name,us_per_call,derived`` summary row per experiment gives the harness
wall time and the experiment's headline quantity.
"""
from __future__ import annotations

import sys
import time


def _csv(rows):
    if not rows:
        print("(empty)")
        return
    cols = list(rows[0])
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))


def _kernel_microbench():
    """Wall-clock of the jnp NVFP4 oracle ops on CPU + modeled v5e kernel
    times from the roofline constants."""
    import jax
    import jax.numpy as jnp

    from benchmarks import costmodel as cm
    from repro.core import quant
    from repro.kernels import ref

    rows = []
    n, k, m = 1408, 2048, 4096
    w = jax.random.normal(jax.random.PRNGKey(0), (n, k), jnp.float32) * 0.05
    x = jax.random.normal(jax.random.PRNGKey(1), (m, k), jnp.float32)
    qt = quant.quantize_fp4(w)

    f_q = jax.jit(lambda w: quant.quantize_fp4(w))
    f_mm = jax.jit(lambda x: ref.fp4_matmul_ref(x, qt.packed, qt.scales,
                                                qt.global_scale, a4=True))
    for name, f, arg, flops, bytes_ in (
            ("quantize_fp4", f_q, w, 0, n * k * 2.53),
            ("fp4_matmul_w4a4", f_mm, x, 2 * m * n * k,
             m * k * 2 + n * k * 0.53)):
        jax.block_until_ready(f(arg))
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(f(arg))
        us = (time.perf_counter() - t0) / 3 * 1e6
        v5e_us = max(flops / cm.PEAK_INT8, bytes_ / cm.HBM_BW) * 1e6
        rows.append(dict(kernel=name, cpu_oracle_us=round(us, 1),
                         modeled_v5e_us=round(v5e_us, 2),
                         flops=flops, bytes=int(bytes_)))
    return rows


def main() -> None:
    from benchmarks import (fig2_routing_dynamics, fig4_lb_gate,
                            fig5_latency_breakdown, fig9_aimd, table1_main,
                            table4_prefill)

    summary = []

    def run_one(name, fn, derived_fn):
        t0 = time.perf_counter()
        rows = fn()
        dt = (time.perf_counter() - t0) * 1e6
        print(f"== {name} ==")
        _csv(rows)
        print()
        summary.append((name, dt, derived_fn(rows)))

    run_one("fig2_routing_dynamics", fig2_routing_dynamics.run,
            lambda r: f"device_imb_p95={max(x['device_imb_p95'] for x in r)}")
    run_one("table1_main", lambda: table1_main.run("main"),
            lambda r: "best_realb_speedup=" + str(max(
                x["speedup"] for x in r if x["strategy"] == "ReaLB")))
    run_one("table2_acc_ext", lambda: table1_main.run("ext", quality=True),
            lambda r: "worst_dacc=" + str(min(
                x["delta_acc_proxy"] for x in r
                if x["strategy"] == "ReaLB")))
    run_one("fig4_lb_gate", fig4_lb_gate.run,
            lambda r: "crossover_tokens=" + str(next(
                (x["tokens_per_rank"] for x in r if x["gemm_frac"] > 0.5),
                -1)))
    run_one("fig5_latency_breakdown", fig5_latency_breakdown.run,
            lambda r: "realb_e2e_reduction_pct=" + str(max(
                x["e2e_reduction_pct"] for x in r
                if x["strategy"] == "ReaLB")))
    run_one("fig9_aimd", fig9_aimd.run,
            lambda r: f"m_d_min={min(x['m_d_min'] for x in r)}")
    run_one("table4_prefill", table4_prefill.run,
            lambda r: "max_speedup=" + str(max(
                x["speedup_prefill_only"] for x in r)))
    run_one("kernel_microbench", _kernel_microbench,
            lambda r: "modeled_v5e_us=" + str(r[-1]["modeled_v5e_us"]))

    print("== summary (name,us_per_call,derived) ==")
    for name, us, derived in summary:
        print(f"{name},{us:.0f},{derived}")


if __name__ == "__main__":
    main()
