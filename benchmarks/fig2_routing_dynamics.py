"""Fig 2: routing dynamics of MMoE inference — device/expert/modality
imbalance and hot-spot drift, per workload.

CSV: workload,expert_imb_mean,expert_imb_p95,device_imb_mean,
     device_imb_p95,vision_ratio_min,vision_ratio_max,hot_flips_per_100it
"""
from __future__ import annotations

from benchmarks import traces as tr


def run(iters: int = 600):
    rows = []
    for name in tr.WORKLOADS:
        s = tr.trace_stats(tr.workload(name, iters=iters))
        rows.append({"workload": name,
                     "expert_imb_mean": round(s["expert_imb_mean"], 2),
                     "expert_imb_p95": round(s["expert_imb_p95"], 2),
                     "device_imb_mean": round(s["device_imb_mean"], 2),
                     "device_imb_p95": round(s["device_imb_p95"], 2),
                     "vision_ratio_min": round(s["vision_ratio_min_mean"], 2),
                     "vision_ratio_max": round(s["vision_ratio_max_mean"], 2),
                     "hot_flips_per_100it":
                         round(s["hot_device_flips_per_100it"], 1)})
    return rows


def main():
    rows = run()
    cols = list(rows[0])
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))


if __name__ == "__main__":
    main()
