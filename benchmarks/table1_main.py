"""Table 1 (+ Table 2 with --suite ext): strategy comparison per model ×
workload — e2e speedup (roofline cost model on shared traces) and ΔAcc
proxy (measured NVFP4 quality drift on the trained tiny MMoE at the
matching compression fraction).

CSV: model,workload,strategy,speedup,moe_layer_ms,fp4_token_frac,
     delta_acc_proxy,logit_kl
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from benchmarks import acc_proxy
from benchmarks import costmodel as cm
from benchmarks import traces as tr
from repro.configs import ReaLBConfig

MAIN_WORKLOADS = ("MMMU", "MathVista", "DynaMath")
EXT_WORKLOADS = ("AI2D", "InfoVQA", "TextVQA", "MMBench")
MODELS = {"Kimi-VL": cm.KIMI_VL, "Qwen3-VL": cm.QWEN3_VL}


def strategies(g, rcfg):
    return [
        ("Baseline", lambda c: cm.sim_baseline(c, g)),
        ("EPLB", lambda c: cm.sim_eplb(c, g)),
        ("Async_EPLB", lambda c: cm.sim_eplb(c, g, async_transfer=True,
                                             name="Async_EPLB")),
        ("FP4-All", lambda c: cm.sim_fp4_all(c, g)),
        ("ReaLB-m1", lambda c: cm.sim_realb(c, g, rcfg, name="ReaLB-m1",
                                            m_fixed=0.0)),
        ("ReaLB-m2", lambda c: cm.sim_realb(c, g, rcfg, name="ReaLB-m2",
                                            m_fixed=0.7)),
        ("ReaLB-seq", lambda c: cm.sim_realb(c, g, rcfg, name="ReaLB-seq",
                                             overlap=False)),
        ("ReaLB", lambda c: cm.sim_realb(c, g, rcfg)),
    ]


def run(suite: str = "main", iters: int = 400, quality: bool = True
        ) -> List[Dict]:
    rows: List[Dict] = []
    names = MAIN_WORKLOADS if suite == "main" else EXT_WORKLOADS
    rcfg = ReaLBConfig()
    qcache: Dict[float, Dict[str, float]] = {}
    params = cfg_t = None
    if quality:
        cfg_t, params = acc_proxy.get_trained_model()
    for mname, g in MODELS.items():
        for wname in names:
            cfg = tr.workload(wname, iters=iters,
                              n_experts=g.n_experts, top_k=g.top_k)
            base = cm.sim_baseline(cfg, g)
            for sname, fn in strategies(g, rcfg):
                r = fn(cfg)
                q = {"delta_acc_proxy": 0.0, "logit_kl": 0.0}
                if quality and r.fp4_token_frac > 0:
                    frac = round(float(np.mean(r.extra["fp4_ranks"]))
                                 / cfg.ep, 2)
                    if frac not in qcache:
                        qcache[frac] = acc_proxy.measure_quality(
                            frac, ep=cfg.ep, params=params, cfg=cfg_t)
                    q = qcache[frac]
                rows.append(dict(
                    model=mname, workload=wname, strategy=sname,
                    speedup=round(r.e2e_speedup(base, g), 3),
                    moe_layer_ms=round(r.mean_layer_ms, 4),
                    fp4_token_frac=round(r.fp4_token_frac, 3),
                    delta_acc_proxy=round(q["delta_acc_proxy"], 3),
                    logit_kl=round(q["logit_kl"], 5)))
    return rows


def main(suite: str = "main"):
    rows = run(suite)
    cols = list(rows[0])
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))


if __name__ == "__main__":
    import sys
    main(sys.argv[1] if len(sys.argv) > 1 else "main")
