"""Hot-loop invariant report: lint + jaxpr audit + collective-census
reconciliation + runtime sentinel, one JSON artifact, non-zero exit on
any violation.

    PYTHONPATH=src python benchmarks/analysis_report.py \
        --out invariant_report.json

Sections (``--only`` filters, comma-separated):

* **lint** — ``repro.analysis.lint`` over ``src/repro``: zero
  unsuppressed RPL findings.
* **audit** — trace the single-host FP4-active MoE step under both
  expert-FFN backends (``jnp`` and the Pallas ``interpret`` kernel) and
  walk the jaxpr: no host callbacks, no f64, every float widening on
  the dispatch/expert path allowlisted, zero collectives on the local
  path.
* **census** — the dispatch path on the (2,4) mesh: the traced jaxpr
  census, the post-XLA HLO census and the FlopByteLedger graph
  prediction must reconcile (jaxpr == ledger exactly; HLO user-slice
  all-to-all exact, all-reduce within the loop-hoisting tolerance).
* **sentinel** — a two-pass serve on the FP4-active profiled arm
  (realb+placement, Γ=8, m_d=0, AIMD off, interpret kernels, tracer and
  profiler live): pass 1 warms every jit entry, an identical pass 2
  must hit the caches exactly — zero recompiles, zero unsanctioned
  device→host syncs.

``--tamper sync`` injects a ``float()`` host pull into the decode hot
window and ``--tamper psum`` an extra collective into the census
harness; both must flip the exit code (pinned by
``tests/test_analysis_report.py``).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# the census section needs the 8-device fake CPU topology, which must be
# pinned before jax initializes
if "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

SECTIONS = ("lint", "audit", "census", "sentinel")


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write the JSON invariant report here")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of sections "
                         f"({', '.join(SECTIONS)})")
    ap.add_argument("--tamper", default=None, choices=["sync", "psum"],
                    help="deliberately break an invariant (CI pins that "
                         "the report catches it): 'sync' = host pull in "
                         "the decode hot window, 'psum' = extra "
                         "collective in the census harness")
    ap.add_argument("--requests", type=int, default=6,
                    help="requests per sentinel serving pass")
    return ap.parse_args(argv)


def _section(fn):
    """Run one section; any exception becomes a failing entry."""
    try:
        out = fn()
        out.setdefault("ok", False)
        return out
    except Exception as e:                       # noqa: BLE001
        return {"ok": False, "error": f"{type(e).__name__}: {e}"}


def run_lint() -> dict:
    from repro.analysis.lint import lint_paths, summarize
    s = summarize(lint_paths([os.path.join(_ROOT, "src", "repro")]))
    s["ok"] = s.pop("files_ok")
    # the per-finding dicts stay; CI surfaces them in the artifact
    return s


def run_audit() -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.analysis.jaxpr_audit import audit_jaxpr
    from repro.configs import ReaLBConfig, get_config, reduced
    from repro.core import ep_moe
    from repro.kernels import ops as kops

    cfg = reduced(get_config("olmoe-1b-7b"))
    e = cfg.moe
    ks = jax.random.split(jax.random.PRNGKey(1), 6)
    D, E, F = cfg.d_model, e.num_experts, e.d_ff
    p = {"router": jax.random.normal(ks[0], (D, E)) * 0.2,
         "w_gate": jax.random.normal(ks[1], (E, D, F)) / np.sqrt(D),
         "w_up": jax.random.normal(ks[2], (E, D, F)) / np.sqrt(D),
         "w_down": jax.random.normal(ks[3], (E, F, D)) / np.sqrt(F)}
    x = jax.random.normal(ks[4], (2, 16, D)) * 0.5
    mod = jax.random.bernoulli(ks[5], 0.6, (2, 16))
    rcfg = ReaLBConfig(gate_gamma=1e-6)          # policy ON: FP4 live
    m = jnp.full((1, 1), 0.9)

    backends = {}
    ok = True
    for backend in ("jnp", "interpret"):
        kops.set_ffn_backend(backend)
        closed = jax.make_jaxpr(
            lambda p_, x_, m_: ep_moe.ep_moe_forward(
                p_, x_, cfg, rcfg, m_, mod, mode="dispatch"))(p, x, m)
        rep = audit_jaxpr(closed)
        b_ok = rep.ok and not rep.census
        ok = ok and b_ok
        backends[backend] = {
            "ok": b_ok, "n_eqns": rep.n_eqns,
            "n_widenings": len(rep.widenings),
            "violations": [v.format() for v in rep.violations],
            "census": rep.census,      # local path: must be empty
        }
    kops.set_ffn_backend("auto")
    return {"ok": ok, "backends": backends}


def run_census(tamper: bool) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.analysis.jaxpr_audit import collective_census_jaxpr
    from repro.configs import ReaLBConfig, get_config, reduced
    from repro.core import ep_moe
    from repro.launch.hlo_analysis import collective_census
    from repro.models.common import shard_map, use_mesh
    from repro.obs.ledger import FlopByteLedger

    cfg = reduced(get_config("olmoe-1b-7b"))
    e = cfg.moe
    ks = jax.random.split(jax.random.PRNGKey(1), 6)
    D, E, F = cfg.d_model, e.num_experts, e.d_ff
    p = {"router": jax.random.normal(ks[0], (D, E)) * 0.2,
         "w_gate": jax.random.normal(ks[1], (E, D, F)) / np.sqrt(D),
         "w_up": jax.random.normal(ks[2], (E, D, F)) / np.sqrt(D),
         "w_down": jax.random.normal(ks[3], (E, F, D)) / np.sqrt(F)}
    x = jax.random.normal(ks[4], (4, 16, D)) * 0.5
    mod = jax.random.bernoulli(ks[5], 0.6, (4, 16))
    rcfg = ReaLBConfig(gate_gamma=10 ** 9)
    L = 3
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    P = jax.sharding.PartitionSpec

    def fwd(p, x, m):
        def step(carry, _):
            x_c, m_c = carry
            y, m_n, aux = ep_moe.ep_moe_forward(p, x_c, cfg, rcfg, m_c,
                                                mod, mode="dispatch")
            if tamper:      # one extra collective per layer
                extra = shard_map(lambda a: jax.lax.psum(a, "model"),
                                  mesh=mesh, in_specs=P(), out_specs=P(),
                                  check_rep=False)(aux["drop_frac"])
                y = y + extra * 0.0
            return (y, m_n), aux
        return jax.lax.scan(step, (x, m), None, length=L)

    with use_mesh(mesh):
        m = jnp.full(ep_moe.moe_state_shape(mesh, 4), 0.9)
        closed = jax.make_jaxpr(fwd)(p, x, m)
        hlo = jax.jit(fwd).lower(p, x, m).compile().as_text()

    jx = collective_census_jaxpr(closed)
    led = FlopByteLedger(cfg, ep=4).predict_graph_census(
        t_local=8, layers=L, itemsize=x.dtype.itemsize)
    hl = collective_census(hlo)
    a2a = hl["user"].get("all-to-all", {"count": 0, "bytes": 0})
    ar = hl["user"].get("all-reduce", {"count": 0, "bytes": 0})

    checks = {
        # jaxpr == ledger, exactly (same capacity formula, same shapes)
        "jaxpr_eq_ledger": all(jx.get(k) == led[k]
                               for k in ("all_to_all", "psum")),
        # HLO user slice: a2a exact; psum lowers to all-reduce, XLA may
        # merge and hoist loop-invariant scalars (count <=, bytes ~5%)
        "hlo_a2a_exact": a2a == led["all_to_all"],
        "hlo_ar_count": 0 < ar["count"] <= led["psum"]["count"],
        "hlo_ar_bytes_tol": abs(ar["bytes"] - led["psum"]["bytes"])
        / led["psum"]["bytes"] <= 0.05,
        "hlo_layers": hl["layers"] == L,
    }
    return {"ok": all(checks.values()), "checks": checks,
            "jaxpr": jx, "ledger": led,
            "hlo_user": hl["user"], "hlo_total": hl["total"]}


def run_sentinel(n_requests: int, tamper: bool) -> dict:
    import jax

    from repro.analysis.sentinel import Sentinel
    from repro.configs import (PlacementConfig, ReaLBConfig, get_config,
                               reduced)
    from repro.kernels import ops as kops
    from repro.models import transformer as tf
    from repro.obs import FlopByteLedger, Profiler, Tracer
    from repro.placement import PlacementManager
    from repro.serving.engine import Engine
    from repro.serving.telemetry import Telemetry
    from repro.workloads import (ArrivalConfig, IterationCostModel,
                                 VirtualClock, arrival_times, make_stream,
                                 profile)

    # the profiled CI arm: realb+placement, deterministic FP4 duty
    cfg = reduced(get_config("moonshot-v1-16b-a3b"))
    kops.set_ffn_backend("interpret")
    rcfg = ReaLBConfig(gate_gamma=8, md_init=0.0, adaptive=False)
    prof = profile("MMMU")
    max_len = 256
    specs = make_stream(
        prof, arrival_times(ArrivalConfig(kind="bursty", rate=12.0,
                                          n_requests=n_requests, seed=0)),
        cfg.vocab_size, seed=1, max_prompt=max_len - prof.max_new_max - 1)
    params = tf.init_model(cfg, jax.random.PRNGKey(0))
    manager = PlacementManager(
        cfg, PlacementConfig(planner="least_loaded", replan_every=8), ep=4)
    telemetry = Telemetry()
    clock = VirtualClock()
    sent = Sentinel()
    sent.arm()
    try:
        eng = Engine(cfg, params, rcfg, max_slots=4, max_len=max_len,
                     prefill_budget=128, clock=clock, telemetry=telemetry,
                     cost_model=IterationCostModel(), placement=manager,
                     virtual_ep=4, tracer=Tracer(clock=clock),
                     profiler=Profiler(FlopByteLedger(
                         cfg, ep=4, fused=kops.ffn_fused()),
                         registry=telemetry.registry),
                     sentinel=sent)
        if tamper:
            orig = eng._decode

            def tampered(*a, **kw):
                out = orig(*a, **kw)
                float(out[0].sum())      # host pull inside the hot window
                return out

            eng._decode = tampered

        def one_pass():
            for spec in specs:
                eng.submit(spec.to_request(d_model=cfg.d_model))
            eng.run()
            eng.drain_migrations()

        one_pass()                       # warmup: every entry compiles
        warm = sent.mark_warm()
        one_pass()                       # identical stream: caches only
    finally:
        sent.disarm()
        kops.set_ffn_backend("auto")
    rep = sent.report()
    rep["warm_counts"] = warm
    rep["n_requests_per_pass"] = n_requests
    return rep


def main(argv=None) -> int:
    args = parse_args(argv)
    only = set((args.only or ",".join(SECTIONS)).split(","))
    unknown = only - set(SECTIONS)
    if unknown:
        raise SystemExit(f"unknown section(s): {sorted(unknown)}")

    report = {"schema": "repro.analysis.v1",
              "tamper": args.tamper, "sections": {}}
    if "lint" in only:
        report["sections"]["lint"] = _section(run_lint)
    if "audit" in only:
        report["sections"]["audit"] = _section(run_audit)
    if "census" in only:
        report["sections"]["census"] = _section(
            lambda: run_census(tamper=args.tamper == "psum"))
    if "sentinel" in only:
        report["sections"]["sentinel"] = _section(
            lambda: run_sentinel(args.requests,
                                 tamper=args.tamper == "sync"))
    report["ok"] = all(s["ok"] for s in report["sections"].values())

    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2, default=str)
        print(f"wrote invariant report -> {args.out}")
    for name, s in report["sections"].items():
        detail = s.get("error", "")
        print(f"  {name}: {'ok' if s['ok'] else 'VIOLATION'}"
              + (f" ({detail})" if detail else ""))
    print(f"invariants: {'ok' if report['ok'] else 'VIOLATED'}")
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
