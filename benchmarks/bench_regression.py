"""Warn-only throughput regression guard for the serving benchmark.

    PYTHONPATH=src python benchmarks/bench_regression.py \
        BENCH_serve.json --baseline benchmarks/BENCH_baseline.json

Compares each arm's ``throughput_tok_per_s`` in a fresh
``BENCH_serve.json`` against the checked-in baseline and prints a
markdown table (arm, baseline tok/s, current tok/s, delta, verdict).
Arms slower than ``baseline * (1 - tolerance)`` are flagged ``WARN``;
arms missing from either file are flagged ``NEW`` / ``GONE``.  When
both files carry the profiler's ``mfu`` key, an MFU drop beyond
``--mfu-tolerance`` (default 10%, tighter than tok/s because the ratio
cancels runner speed) is flagged ``WARN(mfu)``.

The guard **never fails the build** (exit 0 always, unless an input
file is unreadable): serving throughput is measured in real wall
seconds, so it is machine- and load-dependent — CI runners vary by far
more than any single regression worth catching automatically.  The
default tolerance band is therefore wide (30%); the table in the job
summary is the signal, a human is the gate.  Virtual-time quantities
(TTFT/IB/migration bytes) are deterministic and guarded by tests
instead.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict


def load_arms(path: str) -> Dict[str, Dict]:
    with open(path) as f:
        payload = json.load(f)
    return payload.get("arms", payload)


def dispatch_quantize_share(profile: Dict) -> float:
    """The ROADMAP item-1 acceptance number, same arithmetic as
    ``profile_report.py``: measured dispatch + quantize_fp4 seconds over
    the measured forward seconds."""
    phases = profile["phases"]
    fwd_s = float(profile["totals"]["forward_s"])
    kern = sum(float(phases[ph]["measured_s"])
               for ph in ("dispatch", "quantize_fp4") if ph in phases)
    return kern / fwd_s if fwd_s > 0 else 0.0


def compare_profile(profile: Dict, base: Dict, band: float) -> str:
    """Markdown block for the profiled arm's dispatch+quantize_fp4 share
    against the checked-in baseline (warn-only, absolute band).

    The share is a within-run ratio, so unlike tok/s it barely moves with
    runner speed — a narrow absolute band catches the one regression this
    PR class cares about: un-fusing the FP4 path (the quantize stage
    reappearing as visible wall time) or bloating dispatch.
    """
    cur = dispatch_quantize_share(profile)
    ref = float(base["dispatch_quantize_share"])
    verdict = "WARN" if cur > ref + band else "OK"
    meta = profile.get("metadata", {})
    out = ["### profiled arm: dispatch+quantize_fp4 share",
           "",
           "| arm | backend | baseline share | current share | band "
           "| verdict |",
           "|---|---|---:|---:|---:|---|",
           f"| {meta.get('arm', '?')} | {meta.get('ffn_backend', '?')}"
           f"{' (fused)' if meta.get('fused') else ''} | {ref:.3f} "
           f"| {cur:.3f} | +{band:.3f} | {verdict} |"]
    if base.get("ffn_backend") and \
            meta.get("ffn_backend") != base["ffn_backend"]:
        out.append(f"\nnote: baseline was recorded with "
                   f"backend={base['ffn_backend']}")
    return "\n".join(out)


def compare(current: Dict[str, Dict], baseline: Dict[str, Dict],
            tolerance: float, mfu_tolerance: float = 0.10
            ) -> Dict[str, Dict]:
    rows: Dict[str, Dict] = {}
    for arm in sorted(set(current) | set(baseline)):
        cur = current.get(arm, {}).get("throughput_tok_per_s")
        base = baseline.get(arm, {}).get("throughput_tok_per_s")
        if cur is None:
            verdict = "GONE"
        elif base is None:
            verdict = "NEW"
        elif cur < base * (1.0 - tolerance):
            verdict = "WARN"
        else:
            verdict = "OK"
        # MFU rides along under its own (tighter) band: utilization is
        # a flops-over-measured-seconds ratio, so it is less
        # runner-speed-dependent than raw tok/s
        cur_mfu = current.get(arm, {}).get("mfu")
        base_mfu = baseline.get(arm, {}).get("mfu")
        if verdict == "OK" and cur_mfu is not None and base_mfu \
                and cur_mfu < base_mfu * (1.0 - mfu_tolerance):
            verdict = "WARN(mfu)"
        rows[arm] = dict(baseline=base, current=cur, verdict=verdict,
                         delta=(cur / base - 1.0)
                         if cur is not None and base else None,
                         baseline_mfu=base_mfu, current_mfu=cur_mfu)
    return rows


def markdown_table(rows: Dict[str, Dict], tolerance: float) -> str:
    out = [f"### serve_bench throughput vs baseline "
           f"(warn below -{tolerance:.0%})",
           "",
           "| arm | baseline tok/s | current tok/s | delta | "
           "baseline mfu | current mfu | verdict |",
           "|---|---:|---:|---:|---:|---:|---|"]
    for arm, r in rows.items():
        base = f"{r['baseline']:.0f}" if r["baseline"] is not None else "-"
        cur = f"{r['current']:.0f}" if r["current"] is not None else "-"
        delta = f"{r['delta']:+.1%}" if r["delta"] is not None else "-"
        bm = f"{r['baseline_mfu']:.4f}" \
            if r.get("baseline_mfu") is not None else "-"
        cm = f"{r['current_mfu']:.4f}" \
            if r.get("current_mfu") is not None else "-"
        out.append(f"| {arm} | {base} | {cur} | {delta} | {bm} | {cm} "
                   f"| {r['verdict']} |")
    n_warn = sum(r["verdict"].startswith("WARN") for r in rows.values())
    out += ["", f"{n_warn} arm(s) below the tolerance band"
                if n_warn else "all arms within the tolerance band"]
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("current", nargs="?", default=None,
                    help="fresh BENCH_serve.json (omit for a "
                         "profile-only comparison)")
    ap.add_argument("--baseline", default="benchmarks/BENCH_baseline.json",
                    help="checked-in per-arm baseline summaries")
    ap.add_argument("--profile", default=None,
                    help="fresh profile.json from the profiled arm; its "
                         "dispatch+quantize_fp4 share is compared against "
                         "the baseline's 'profile' entry")
    ap.add_argument("--share-band", type=float, default=0.05,
                    help="absolute increase of the dispatch+quantize_fp4 "
                         "share that triggers a WARN (the share is a "
                         "within-run ratio, so the band can be narrow)")
    ap.add_argument("--tolerance", type=float, default=0.30,
                    help="relative slowdown that triggers a WARN "
                         "(default 0.30: wall-clock throughput on shared "
                         "CI runners is noisy)")
    ap.add_argument("--mfu-tolerance", type=float, default=0.10,
                    help="relative MFU drop that triggers a WARN(mfu) "
                         "(tighter than tok/s: utilization is a ratio, "
                         "less runner-dependent)")
    args = ap.parse_args(argv)
    if args.current is None and args.profile is None:
        ap.error("nothing to compare: pass BENCH_serve.json, --profile, "
                 "or both")
    try:
        if args.current is not None:
            current = load_arms(args.current)
            baseline = load_arms(args.baseline)
        with open(args.baseline) as f:
            base_profile = json.load(f).get("profile")
        if args.profile is not None:
            with open(args.profile) as f:
                profile = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench_regression: cannot read inputs: {e}", file=sys.stderr)
        return 1
    if args.current is not None:
        rows = compare(current, baseline, args.tolerance,
                       mfu_tolerance=args.mfu_tolerance)
        print(markdown_table(rows, args.tolerance))
    if args.profile is not None:
        if base_profile is None:
            print("\nno 'profile' entry in the baseline; skipping the "
                  "dispatch+quantize_fp4 share check")
        else:
            print()
            print(compare_profile(profile, base_profile, args.share_band))
    return 0    # warn-only by design: the table is the signal


if __name__ == "__main__":
    raise SystemExit(main())
