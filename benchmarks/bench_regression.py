"""Warn-only throughput regression guard for the serving benchmark.

    PYTHONPATH=src python benchmarks/bench_regression.py \
        BENCH_serve.json --baseline benchmarks/BENCH_baseline.json

Compares each arm's ``throughput_tok_per_s`` in a fresh
``BENCH_serve.json`` against the checked-in baseline and prints a
markdown table (arm, baseline tok/s, current tok/s, delta, verdict).
Arms slower than ``baseline * (1 - tolerance)`` are flagged ``WARN``;
arms missing from either file are flagged ``NEW`` / ``GONE``.  When
both files carry the profiler's ``mfu`` key, an MFU drop beyond
``--mfu-tolerance`` (default 10%, tighter than tok/s because the ratio
cancels runner speed) is flagged ``WARN(mfu)``.

The guard **never fails the build** (exit 0 always, unless an input
file is unreadable): serving throughput is measured in real wall
seconds, so it is machine- and load-dependent — CI runners vary by far
more than any single regression worth catching automatically.  The
default tolerance band is therefore wide (30%); the table in the job
summary is the signal, a human is the gate.  Virtual-time quantities
(TTFT/IB/migration bytes) are deterministic and guarded by tests
instead.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict


def load_arms(path: str) -> Dict[str, Dict]:
    with open(path) as f:
        payload = json.load(f)
    return payload.get("arms", payload)


def compare(current: Dict[str, Dict], baseline: Dict[str, Dict],
            tolerance: float, mfu_tolerance: float = 0.10
            ) -> Dict[str, Dict]:
    rows: Dict[str, Dict] = {}
    for arm in sorted(set(current) | set(baseline)):
        cur = current.get(arm, {}).get("throughput_tok_per_s")
        base = baseline.get(arm, {}).get("throughput_tok_per_s")
        if cur is None:
            verdict = "GONE"
        elif base is None:
            verdict = "NEW"
        elif cur < base * (1.0 - tolerance):
            verdict = "WARN"
        else:
            verdict = "OK"
        # MFU rides along under its own (tighter) band: utilization is
        # a flops-over-measured-seconds ratio, so it is less
        # runner-speed-dependent than raw tok/s
        cur_mfu = current.get(arm, {}).get("mfu")
        base_mfu = baseline.get(arm, {}).get("mfu")
        if verdict == "OK" and cur_mfu is not None and base_mfu \
                and cur_mfu < base_mfu * (1.0 - mfu_tolerance):
            verdict = "WARN(mfu)"
        rows[arm] = dict(baseline=base, current=cur, verdict=verdict,
                         delta=(cur / base - 1.0)
                         if cur is not None and base else None,
                         baseline_mfu=base_mfu, current_mfu=cur_mfu)
    return rows


def markdown_table(rows: Dict[str, Dict], tolerance: float) -> str:
    out = [f"### serve_bench throughput vs baseline "
           f"(warn below -{tolerance:.0%})",
           "",
           "| arm | baseline tok/s | current tok/s | delta | "
           "baseline mfu | current mfu | verdict |",
           "|---|---:|---:|---:|---:|---:|---|"]
    for arm, r in rows.items():
        base = f"{r['baseline']:.0f}" if r["baseline"] is not None else "-"
        cur = f"{r['current']:.0f}" if r["current"] is not None else "-"
        delta = f"{r['delta']:+.1%}" if r["delta"] is not None else "-"
        bm = f"{r['baseline_mfu']:.4f}" \
            if r.get("baseline_mfu") is not None else "-"
        cm = f"{r['current_mfu']:.4f}" \
            if r.get("current_mfu") is not None else "-"
        out.append(f"| {arm} | {base} | {cur} | {delta} | {bm} | {cm} "
                   f"| {r['verdict']} |")
    n_warn = sum(r["verdict"].startswith("WARN") for r in rows.values())
    out += ["", f"{n_warn} arm(s) below the tolerance band"
                if n_warn else "all arms within the tolerance band"]
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("current", help="fresh BENCH_serve.json")
    ap.add_argument("--baseline", default="benchmarks/BENCH_baseline.json",
                    help="checked-in per-arm baseline summaries")
    ap.add_argument("--tolerance", type=float, default=0.30,
                    help="relative slowdown that triggers a WARN "
                         "(default 0.30: wall-clock throughput on shared "
                         "CI runners is noisy)")
    ap.add_argument("--mfu-tolerance", type=float, default=0.10,
                    help="relative MFU drop that triggers a WARN(mfu) "
                         "(tighter than tok/s: utilization is a ratio, "
                         "less runner-dependent)")
    args = ap.parse_args(argv)
    try:
        current = load_arms(args.current)
        baseline = load_arms(args.baseline)
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench_regression: cannot read inputs: {e}", file=sys.stderr)
        return 1
    rows = compare(current, baseline, args.tolerance,
                   mfu_tolerance=args.mfu_tolerance)
    print(markdown_table(rows, args.tolerance))
    return 0    # warn-only by design: the table is the signal


if __name__ == "__main__":
    raise SystemExit(main())
