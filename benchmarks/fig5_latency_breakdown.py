"""Fig 5: fine-grained MoE latency analysis on DynaMath — per-strategy
mean/percentile layer latency, hot-rank speedup, and MoE time share.

CSV: model,strategy,moe_ms_mean,moe_ms_p95,hotrank_speedup,
     moe_e2e_share,e2e_reduction_pct
"""
from __future__ import annotations

import numpy as np

from benchmarks import costmodel as cm
from benchmarks import traces as tr
from repro.configs import ReaLBConfig


def run(iters: int = 400):
    rcfg = ReaLBConfig()
    rows = []
    for mname, g in (("Kimi-VL", cm.KIMI_VL), ("Qwen3-VL", cm.QWEN3_VL)):
        cfg = tr.workload("DynaMath", iters=iters, n_experts=g.n_experts,
                          top_k=g.top_k)
        sims = [cm.sim_baseline(cfg, g), cm.sim_eplb(cfg, g),
                cm.sim_fp4_all(cfg, g),
                cm.sim_realb(cfg, g, rcfg, name="ReaLB-seq", overlap=False),
                cm.sim_realb(cfg, g, rcfg)]
        base = sims[0]
        # hot-rank speedup: per-iteration straggler time ratio
        def hotrank(sim):
            return float(np.mean(base.layer_times / sim.layer_times))
        for s in sims:
            ratio = s.layer_times.mean() / base.layer_times.mean()
            share = g.moe_time_share
            e2e_red = 100 * (1 - (1 - share + share * ratio))
            rows.append(dict(
                model=mname, strategy=s.name,
                moe_ms_mean=round(s.mean_layer_ms, 4),
                moe_ms_p95=round(float(np.percentile(
                    s.layer_times, 95) * 1e3), 4),
                hotrank_speedup=round(hotrank(s), 3),
                moe_e2e_share=round(share * ratio
                                    / (1 - share + share * ratio), 3),
                e2e_reduction_pct=round(e2e_red, 2)))
    return rows


def main():
    rows = run()
    cols = list(rows[0])
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))


if __name__ == "__main__":
    main()
