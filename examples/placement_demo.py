"""Placement vs replication vs ReaLB vs the hybrids, on one vision-burst
routing trace.

Runs the analytic cost-model simulators (pure numpy, CPU, well under a
minute) over a single seeded trace with abrupt vision-hot-spot jumps and
contrasts the six arms of the comparison:

* ``off``             — contiguous placement, BF16 everywhere
* ``realb``           — ReaLB's AIMD FP4 compression (zero migration)
* ``placement``       — predictive least-loaded remapping (pays migration)
* ``realb+placement`` — remap the slow skew, compress the bursts
* ``replicate``       — EPLB-style redundant experts: duplicate the
  hottest (vision-heavy) experts into spare slots and split their tokens
  round-robin across the replicas (pays replica-slab copies)
* ``realb+replicate`` — the precision hybrid: replicas flatten the
  predictable skew, FP4 absorbs the bursts the replica set missed

Prints per-arm IB_d / layer-time / FP4 / migration summaries plus a
coarse IB_d trajectory so the complementary timescales are visible: after
each hot-spot jump the placement arm stays imbalanced until its next
replan, while the hybrid's FP4 duty covers exactly that gap.  Replication
can go where bijective placement cannot — a single expert hotter than a
whole rank's fair share is un-placeable but splits cleanly.

    PYTHONPATH=src python examples/placement_demo.py
"""
from __future__ import annotations

import pathlib
import sys

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

from benchmarks import costmodel as cm
from benchmarks import traces as tr
from repro.configs import ReaLBConfig

BINS = 12


def sparkline(xs, bins=BINS):
    """Coarse text trajectory: mean per time-bin, mapped to ramp glyphs."""
    xs = np.asarray(xs)
    chunks = np.array_split(xs, bins)
    means = np.array([c.mean() for c in chunks])
    glyphs = " .:-=+*#%@"
    lo, hi = means.min(), means.max()
    idx = np.zeros(bins, int) if hi <= lo else \
        ((means - lo) / (hi - lo) * (len(glyphs) - 1)).astype(int)
    return "".join(glyphs[i] for i in idx), means


def main() -> int:
    # vision-burst trace: strong skew, frequent abrupt hot-spot jumps
    cfg = tr.TraceConfig(name="vision-burst", iters=600, jump_every=150,
                         vision_frac_mean=0.8, zipf_a=1.3, seed=3)
    g = cm.KIMI_VL
    rcfg = ReaLBConfig()

    arms = [
        ("off", cm.sim_baseline(cfg, g)),
        ("realb", cm.sim_realb(cfg, g, rcfg, name="realb")),
        ("placement", cm.sim_placement(cfg, g, planner="least_loaded",
                                       interval=60, name="placement")),
        ("realb+placement", cm.sim_realb_placement(
            cfg, g, rcfg, planner="least_loaded", interval=60,
            name="realb+placement")),
        ("replicate", cm.sim_replication(cfg, g, interval=60,
                                         name="replicate")),
        ("realb+replicate", cm.sim_realb_replication(
            cfg, g, rcfg, interval=60, name="realb+replicate")),
    ]
    base = arms[0][1]

    print(f"trace: {cfg.iters} iters, EP={cfg.ep}, "
          f"jump_every={cfg.jump_every}, vision~{cfg.vision_frac_mean}")
    print(f"{'arm':16s} {'layer_ms':>8s} {'IB mean':>8s} {'IB p95':>7s} "
          f"{'fp4%tok':>8s} {'moved GB':>9s} {'e2e x':>6s}")
    for name, r in arms:
        ib = np.asarray(r.extra["ib_global"])
        moved = r.extra.get("moved_bytes", [0.0])[0] / 1e9
        print(f"{name:16s} {r.mean_layer_ms:8.3f} {ib.mean():8.2f} "
              f"{np.percentile(ib, 95):7.2f} {r.fp4_token_frac:8.2f} "
              f"{moved:9.2f} {r.e2e_speedup(base, g):6.3f}")

    print(f"\nIB_d trajectory ({BINS} bins of {cfg.iters // BINS} iters; "
          f"hot-spot jumps every {cfg.jump_every}):")
    for name, r in arms:
        line, means = sparkline(r.extra["ib_global"])
        print(f"  {name:16s} |{line}|  "
              f"{means.min():.2f}..{means.max():.2f}")
    print("\nreading: 'placement' re-flattens IB only at each replan and "
          "drifts between them; 'realb' leaves IB untouched and pays FP4 "
          "on every burst; 'replicate' splits the hot experts themselves, "
          "so it flattens skew that no bijective remap can (an expert "
          "hotter than a rank's fair share) at a higher slab-copy cost; "
          "the hybrids reach the lowest layer times — the table absorbs "
          "the predictable skew so fewer tokens need compression than "
          "under ReaLB alone, at a bounded migration cost.")

    # ---- per-layer tables: depth-varying skew -------------------------
    # each layer's hot-expert set drifts independently (paper Fig. 2), so
    # a shared table balances a depth average no single layer has; the
    # per-layer arms plan one table per layer and migrate layer-diffs
    n_layers = 4
    dcfg = tr.TraceConfig(name="depth-varying", iters=600, jump_every=150,
                          vision_frac_mean=0.8, zipf_a=1.3, seed=3)
    layer_arms = [
        ("placement shared", cm.sim_placement_layers(
            dcfg, g, n_layers=n_layers, per_layer=False, interval=60)),
        ("placement /L", cm.sim_placement_layers(
            dcfg, g, n_layers=n_layers, per_layer=True, interval=60)),
        ("replicate shared", cm.sim_replication_layers(
            dcfg, g, n_layers=n_layers, per_layer=False, interval=60)),
        ("replicate /L", cm.sim_replication_layers(
            dcfg, g, n_layers=n_layers, per_layer=True, interval=60)),
    ]
    print(f"\nper-layer tables on a depth-varying trace "
          f"({n_layers} independently drifting layers; IB = depth-peak "
          f"rank imbalance):")
    print(f"{'arm':18s} {'layer_ms':>8s} {'IB mean':>8s} {'IB p95':>7s} "
          f"{'moved GB':>9s}")
    for name, r in layer_arms:
        ib = np.asarray(r.extra["ib_global"])
        moved = r.extra.get("moved_bytes", [0.0])[0] / 1e9
        print(f"{name:18s} {r.mean_layer_ms:8.3f} {ib.mean():8.2f} "
              f"{np.percentile(ib, 95):7.2f} {moved:9.2f}")
    for name, r in layer_arms:
        line, means = sparkline(r.extra["ib_global"])
        print(f"  {name:18s} |{line}|  "
              f"{means.min():.2f}..{means.max():.2f}")
    print("\nreading: the shared arms chase the depth-summed skew — each "
          "replan helps some layers and hurts others, so the depth-peak "
          "IB stays high; the /L arms flatten every layer against its "
          "own skew AND move fewer bytes, because a layer-diff ships "
          "only the layers whose plan changed.")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
