"""Quickstart: the whole system in one minute on CPU.

    PYTHONPATH=src python examples/quickstart.py

1. builds a tiny Kimi-VL-backbone-family MMoE (moonshot-v1-16b-a3b reduced),
2. runs a training step (loss + MoE aux losses),
3. prefills a multimodal prompt and decodes a few tokens with ReaLB live,
4. shows the ReaLB policy making a precision decision on a skewed load.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ReaLBConfig, get_config, reduced
from repro.core import init_m_state
from repro.core.policy import realb_policy
from repro.models import transformer as tf

# 1) model ------------------------------------------------------------------
cfg = reduced(get_config("moonshot-v1-16b-a3b"))
rcfg = ReaLBConfig(gate_gamma=16)       # tiny gate so the demo activates
params = tf.init_model(cfg, jax.random.PRNGKey(0))
n_params = sum(p.size for p in jax.tree.leaves(params))
print(f"model: {cfg.name} (reduced) — {n_params/1e6:.2f}M params, "
      f"{cfg.moe.num_experts} experts top-{cfg.moe.top_k}")

# 2) one training step --------------------------------------------------------
B, S = 4, 32
rng = np.random.default_rng(0)
tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
modality = jnp.asarray(rng.random((B, S)) < 0.6)
batch = {"tokens": tokens, "labels": tokens, "modality": modality}
m = init_m_state(1, 1, rcfg)
loss, (m, metrics) = tf.train_loss(params, cfg, rcfg, batch, m)
print(f"train: loss={float(loss):.3f} lb_loss={float(metrics['lb_loss']):.3f}")

# 3) prefill + decode ---------------------------------------------------------
res = tf.prefill_forward(params, cfg, rcfg, batch, m, cache_len=S + 8)
cache, m = res.cache, res.m_state
tok = jnp.argmax(res.logits, -1)[:, None].astype(jnp.int32)
pos = jnp.full((B,), S, jnp.int32)
text = [int(t) for t in tok[:, 0]]
for step in range(4):
    out = tf.decode_forward(params, cfg, rcfg,
                            {"tokens": tok, "pos": pos}, cache, m)
    cache, m = out.cache, out.m_state
    tok = jnp.argmax(out.logits, -1)[:, None].astype(jnp.int32)
    pos = pos + 1
    text.append(int(tok[0, 0]))
print(f"serve: greedy continuation of sequence 0 -> {text}")

# 4) the ReaLB decision, directly --------------------------------------------
load = jnp.asarray([900.0, 300.0, 350.0, 250.0])   # rank 0 is a straggler
vis = jnp.asarray([850.0, 60.0, 180.0, 50.0])      # ...and vision-heavy
m_d = jnp.full((4,), 0.9)
dec = realb_policy(load, vis, m_d, ReaLBConfig(gate_gamma=1000))
print(f"policy: IB_d={np.round(np.asarray(dec.ib_d),2)} "
      f"hotspots={np.asarray(dec.hotspots)} "
      f"-> FP4 ranks={np.asarray(dec.use_fp4)} "
      f"(M_d -> {np.round(np.asarray(dec.m_new), 2)})")
print("rank 0 exceeds IB>C with R_v>M_d ⇒ executes its experts in FP4; "
      "its quantization is overlapped with the dispatch all-to-all.")
