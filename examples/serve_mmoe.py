"""Serving example: a multimodal workload stream through the
chunked-prefill continuous-batching engine with ReaLB active.

Requests come from the MMMU workload profile (vision-heavy prompts) via
the repro.workloads generators; prefill is batched and token-budgeted, so
even this tiny run drives the MoE into the large-batch regime where the
LB gate opens.

    PYTHONPATH=src python examples/serve_mmoe.py
"""
from repro.launch import serve as serve_mod

if __name__ == "__main__":
    serve_mod.main(["--arch", "moonshot-v1-16b-a3b", "--preset", "tiny",
                    "--workload", "MMMU", "--requests", "10",
                    "--max-new", "6", "--slots", "4",
                    "--prefill-budget", "128"])
