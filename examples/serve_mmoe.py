"""Serving example: batched multimodal requests through the
continuous-batching engine with ReaLB active.

    PYTHONPATH=src python examples/serve_mmoe.py
"""
from repro.launch import serve as serve_mod

if __name__ == "__main__":
    serve_mod.main(["--arch", "moonshot-v1-16b-a3b", "--preset", "tiny",
                    "--requests", "10", "--max-new", "6", "--slots", "4"])
