"""End-to-end training example: a ~7M-param MMoE for a few hundred steps
with the full production substrate — deterministic multimodal pipeline,
AdamW, async checkpointing, NaN guard, and byte-exact restart.

    PYTHONPATH=src python examples/train_tiny_mmoe.py [--steps 200]

Midway through, the script simulates a preemption (drops the in-memory
state) and resumes from the latest checkpoint, verifying the loss curve
continues where it left off.
"""
import argparse
import shutil
import tempfile

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="olmoe-1b-7b")
    args = ap.parse_args()

    ckpt = tempfile.mkdtemp(prefix="repro_tiny_")
    half = max(args.steps // 2, 10)
    try:
        print(f"=== phase 1: train to step {half} ===")
        train_mod.main(["--arch", args.arch, "--preset", "tiny",
                        "--steps", str(half), "--batch", "8",
                        "--seq", "64", "--ckpt-dir", ckpt,
                        "--checkpoint-every", "25", "--multimodal"])
        print("=== simulated preemption: restarting from checkpoint ===")
        train_mod.main(["--arch", args.arch, "--preset", "tiny",
                        "--steps", str(args.steps), "--batch", "8",
                        "--seq", "64", "--ckpt-dir", ckpt,
                        "--checkpoint-every", "25", "--multimodal"])
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    main()
