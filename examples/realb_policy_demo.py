"""ReaLB controller demo: the AIMD threshold reacting to a congestion wave.

    PYTHONPATH=src python examples/realb_policy_demo.py

Feeds the real controller (repro.core.policy) a routing trace whose
imbalance spikes mid-run (as in paper Fig 9) and prints the sawtooth of
M_d: multiplicative decrease while IB_global > τ, additive recovery after.
"""
import jax.numpy as jnp
import numpy as np

from repro.configs import ReaLBConfig
from repro.core.policy import realb_policy

EP = 8
rcfg = ReaLBConfig(gate_gamma=100)
rng = np.random.default_rng(0)
m = jnp.full((EP,), rcfg.md_init)

print(f"{'it':>4} {'IB':>6} {'M_d mean':>9} {'fp4 ranks':>9}  regime")
for it in range(60):
    base = rng.uniform(900, 1100, EP)
    if 20 <= it < 40:                       # congestion wave
        base[it % EP] *= 3.5
    vis = base * np.clip(rng.normal(0.7, 0.2, EP), 0, 1)
    dec = realb_policy(jnp.asarray(base), jnp.asarray(vis), m, rcfg)
    m = dec.m_new
    if it % 2 == 0:
        regime = "CONGESTED" if float(dec.ib_global) > rcfg.tau else "ok"
        print(f"{it:>4} {float(dec.ib_global):>6.2f} "
              f"{float(m.mean()):>9.3f} "
              f"{int(np.asarray(dec.use_fp4).sum()):>9}  {regime}")
